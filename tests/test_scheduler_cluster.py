"""Cluster-capacity scheduler: admission/reservation invariants under
random interleavings, EASY backfill, fair-share + priority ordering,
dispatch reentrancy, and the virtual runner's terminal-event contract."""
import numpy as np
import pytest

from repro.core.engine.cluster import CapacityError, Cluster
from repro.core.engine.events import (EventBus, TOPIC_CONTAINER_STATUS,
                                      TOPIC_SCHEDULER)
from repro.core.engine.launcher import Runner, VirtualRunner
from repro.core.engine.lifecycle import JobState
from repro.core.engine.monitor import JobMonitor
from repro.core.engine.registry import JobRegistry, JobSpec
from repro.core.engine.scheduler import Scheduler
from repro.core.provision.pricing import CPU_PRICING


def _spec(name="j", user="u", duration=1.0, resources=None, priority=0):
    return JobSpec(name=name, project="p", user=user, duration=duration,
                   resources=resources or {}, priority=priority)


def _engine(cluster=None, quota_k=100, policy="fair", backfill=True):
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus)
    sched = Scheduler(registry, runner, bus, quota_k=quota_k,
                      cluster=cluster, policy=policy, backfill=backfill)
    return registry, bus, runner, sched


def _submit(registry, sched, spec):
    job = registry.submit(spec)
    sched.submit(job)
    return job


# -- cluster model -----------------------------------------------------
def test_cluster_from_pricing_and_reserve_release():
    cl = Cluster.from_pricing(CPU_PRICING, nodes=2)
    assert cl.capacity == {"vcpu": 16.0, "mem_mb": 16384.0}
    cl.reserve("a", {"vcpu": 8, "mem_mb": 8192})
    assert cl.fits({"vcpu": 8, "mem_mb": 8192})
    cl.reserve("b", {"vcpu": 8, "mem_mb": 8192})
    assert not cl.fits({"vcpu": 0.5})         # vcpu exhausted
    with pytest.raises(CapacityError):
        cl.reserve("c", {"vcpu": 1, "mem_mb": 512})
    # release is idempotent
    assert cl.release("a") == {"vcpu": 8.0, "mem_mb": 8192.0}
    assert cl.release("a") is None
    assert cl.fits({"vcpu": 8, "mem_mb": 8192})
    # missing dims are charged at the pricing minimum
    assert cl.charge({}) == {"vcpu": 0.5, "mem_mb": 512.0}


@pytest.mark.parametrize("seed", range(6))
def test_capacity_never_oversubscribed_random_interleavings(seed):
    """Property: across random submit/kill/complete interleavings the
    reserved amounts never exceed capacity on any dimension."""
    rng = np.random.default_rng(seed)
    cl = Cluster.from_pricing(CPU_PRICING, nodes=1)   # 8 vcpu, 8192 MB
    registry, bus, runner, sched = _engine(cluster=cl, quota_k=5)
    high_water = {n: 0.0 for n in cl.capacity}

    def audit(_msg):
        for n, used in cl.used.items():
            high_water[n] = max(high_water[n], used)
            assert used <= cl.capacity[n] + 1e-9, (n, used)

    bus.subscribe(TOPIC_CONTAINER_STATUS, audit)
    jobs = []
    for i in range(120):
        op = rng.random()
        if op < 0.6 or not jobs:
            res = {"vcpu": float(rng.choice([0.5, 1, 2, 4, 8])),
                   "mem_mb": float(rng.choice([512, 2048, 8192]))}
            jobs.append(_submit(registry, sched, _spec(
                name=f"j{i}", user=f"u{rng.integers(3)}",
                duration=float(rng.uniform(0.5, 20)), resources=res)))
            audit(None)
        elif op < 0.75:
            sched.kill(jobs[int(rng.integers(len(jobs)))].job_id)
            audit(None)
        else:
            runner.step()
    sched.run_to_completion()
    audit(None)
    assert all(v <= cl.capacity[n] + 1e-9 for n, v in high_water.items())
    assert all(registry.get(j.job_id).state in
               (JobState.FINISHED, JobState.KILLED) for j in jobs)
    # everything was released at the end
    assert all(v == 0.0 for v in cl.used.values())


def test_infeasible_job_fails_fast():
    cl = Cluster({"vcpu": 4.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl)
    j = _submit(registry, sched, _spec(resources={"vcpu": 64}))
    job = registry.get(j.job_id)
    assert job.state == JobState.FAILED
    assert "exceed cluster capacity" in job.error


# -- unknown-dimension charge bugfix -----------------------------------
def test_charge_keeps_unknown_dimensions_and_rejects():
    """A job requesting a dimension the cluster does not have (tpu on a
    CPU-only cluster) must not be admitted as if the request were free."""
    cl = Cluster({"vcpu": 4.0}, {"vcpu": 0.5})
    charge = cl.charge({"vcpu": 1, "tpu": 8})
    assert charge["tpu"] == 8.0              # kept, not dropped
    assert not cl.fits({"vcpu": 1, "tpu": 8})
    assert not cl.ever_fits({"vcpu": 1, "tpu": 8})
    with pytest.raises(CapacityError):
        cl.reserve("a", {"vcpu": 1, "tpu": 8})
    assert cl.used["vcpu"] == 0.0            # nothing leaked
    # a zero-amount unknown dimension is harmless
    assert cl.ever_fits({"vcpu": 1, "tpu": 0})


def test_unknown_resource_dim_fails_fast_at_submit():
    cl = Cluster({"vcpu": 4.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl)
    j = _submit(registry, sched, _spec(resources={"vcpu": 1, "tpu": 8}))
    job = registry.get(j.job_id)
    assert job.state == JobState.FAILED
    assert "tpu" in job.error and "exceed cluster capacity" in job.error


# -- EASY backfill -----------------------------------------------------
def _track_starts(runner):
    starts = {}
    orig = runner.launch

    def launch(job):
        starts[job.job_id] = runner.now
        orig(job)
    runner.launch = launch
    return starts


def test_backfill_small_job_overtakes_without_delaying_blocked():
    """A: 3/4 vcpu for 10s. B (4 vcpu) blocks at the head until t=10.
    C (1 vcpu, 2s) fits the hole and finishes before B's shadow start, so
    it overtakes B — and B still starts exactly at t=10."""
    cl = Cluster({"vcpu": 4.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl, quota_k=100)
    starts = _track_starts(runner)
    a = _submit(registry, sched, _spec("A", duration=10.0,
                                      resources={"vcpu": 3}))
    b = _submit(registry, sched, _spec("B", duration=5.0,
                                      resources={"vcpu": 4}))
    c = _submit(registry, sched, _spec("C", duration=2.0,
                                      resources={"vcpu": 1}))
    assert registry.get(c.job_id).state == JobState.RUNNING   # backfilled
    assert registry.get(b.job_id).state == JobState.QUEUED
    sched.run_to_completion()
    assert starts[c.job_id] == pytest.approx(0.0)
    assert starts[b.job_id] == pytest.approx(10.0)   # not delayed by C
    assert runner.now == pytest.approx(15.0)
    assert sched.stats["backfilled"] == 1


def test_backfill_rejects_job_that_would_delay_blocked_head():
    """C runs 20s > shadow (t=10) and doesn't fit the spare capacity after
    B starts, so EASY must hold it back."""
    cl = Cluster({"vcpu": 4.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl, quota_k=100)
    starts = _track_starts(runner)
    _submit(registry, sched, _spec("A", duration=10.0,
                                   resources={"vcpu": 3}))
    b = _submit(registry, sched, _spec("B", duration=5.0,
                                       resources={"vcpu": 4}))
    c = _submit(registry, sched, _spec("C", duration=20.0,
                                       resources={"vcpu": 1}))
    assert registry.get(c.job_id).state == JobState.QUEUED
    sched.run_to_completion()
    assert starts[b.job_id] == pytest.approx(10.0)
    assert starts[c.job_id] >= 10.0


def test_backfill_jobs_cannot_collectively_delay_blocked_head():
    """Two long backfill candidates each fit the spare capacity alone but
    not together — admitting both would push the blocked job past its
    shadow start, so only one may launch (spare is consumed as backfill
    jobs are admitted)."""
    cl = Cluster({"vcpu": 16.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl, quota_k=100)
    starts = _track_starts(runner)
    _submit(registry, sched, _spec("A", duration=100.0,
                                   resources={"vcpu": 8}))
    b = _submit(registry, sched, _spec("B", duration=5.0,
                                       resources={"vcpu": 10}))
    c1 = _submit(registry, sched, _spec("C1", duration=10_000.0,
                                        resources={"vcpu": 3.5}))
    c2 = _submit(registry, sched, _spec("C2", duration=10_000.0,
                                        resources={"vcpu": 3.5}))
    # spare after B's shadow start (t=100) is 16-10=6: C1 (3.5) fits and
    # consumes it; C2 (3.5 > 2.5 left) must wait
    assert registry.get(c1.job_id).state == JobState.RUNNING
    assert registry.get(c2.job_id).state == JobState.QUEUED
    sched.run_to_completion()
    assert starts[b.job_id] == pytest.approx(100.0)   # not delayed


def test_fifo_policy_convoys_behind_blocked_head():
    cl = Cluster({"vcpu": 4.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl, policy="fifo",
                                           backfill=False)
    _submit(registry, sched, _spec("A", duration=10.0,
                                   resources={"vcpu": 3}))
    _submit(registry, sched, _spec("B", duration=5.0,
                                   resources={"vcpu": 4}))
    c = _submit(registry, sched, _spec("C", duration=2.0,
                                       resources={"vcpu": 1}))
    assert registry.get(c.job_id).state == JobState.QUEUED   # convoy
    sched.run_to_completion()
    assert runner.now == pytest.approx(17.0)   # A(10) -> B(15) -> C(17)


# -- fair share + priority --------------------------------------------
def test_fair_share_interleaves_users():
    cl = Cluster({"vcpu": 1.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl, quota_k=100)
    starts = _track_starts(runner)
    a = [_submit(registry, sched, _spec(f"a{i}", user="alice", duration=1.0,
                                       resources={"vcpu": 1}))
         for i in range(4)]
    b = [_submit(registry, sched, _spec(f"b{i}", user="bob", duration=1.0,
                                       resources={"vcpu": 1}))
         for i in range(2)]
    sched.run_to_completion()
    order = sorted(starts, key=starts.get)
    # bob's first job runs right after alice's first, not after her whole
    # backlog (strict FIFO would give a0 a1 a2 a3 b0 b1)
    assert order.index(b[0].job_id) == 1
    assert starts[b[1].job_id] < starts[a[3].job_id]


def test_queue_priority_preempts_ordering():
    cl = Cluster({"vcpu": 1.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl, quota_k=100)
    sched.configure_queue("p", "vip", priority=10)
    starts = _track_starts(runner)
    _submit(registry, sched, _spec("a0", user="alice", duration=1.0,
                                   resources={"vcpu": 1}))
    a1 = _submit(registry, sched, _spec("a1", user="alice", duration=1.0,
                                        resources={"vcpu": 1}))
    v = _submit(registry, sched, _spec("v", user="vip", duration=1.0,
                                       resources={"vcpu": 1}))
    sched.run_to_completion()
    assert starts[v.job_id] < starts[a1.job_id]


def test_job_level_priority_orders_within_queue():
    cl = Cluster({"vcpu": 1.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl, quota_k=100)
    starts = _track_starts(runner)
    _submit(registry, sched, _spec("j0", duration=1.0,
                                   resources={"vcpu": 1}))
    low = _submit(registry, sched, _spec("low", duration=1.0,
                                         resources={"vcpu": 1}))
    hi = _submit(registry, sched, _spec("hi", duration=1.0,
                                        resources={"vcpu": 1}, priority=5))
    sched.run_to_completion()
    assert starts[hi.job_id] < starts[low.job_id]


# -- dispatch reentrancy (regression) ----------------------------------
class InstantRunner(Runner):
    """Publishes the terminal status synchronously from inside launch() —
    the pathological fast-job case that used to re-enter _maybe_launch."""

    def __init__(self, registry, bus):
        self.registry = registry
        self.bus = bus
        self.launch_counts = {}
        self.concurrent = 0
        self.max_concurrent = 0
        self.held = set()

    def launch(self, job):
        self.launch_counts[job.job_id] = \
            self.launch_counts.get(job.job_id, 0) + 1
        self.concurrent += 1
        self.max_concurrent = max(self.max_concurrent, self.concurrent)
        self.registry.set_state(job.job_id, JobState.RUNNING)
        if job.job_id in self.held:
            return
        self.finish(job.job_id)

    def finish(self, job_id):
        job = self.registry.get(job_id)
        job.runtime = 0.0
        self.concurrent -= 1
        self.registry.set_state(job_id, JobState.FINISHED)
        self.bus.publish(TOPIC_CONTAINER_STATUS,
                         {"job_id": job_id, "status": "FINISHED"})


def test_reentrant_terminal_events_no_double_launch_no_recursion():
    registry = JobRegistry()
    bus = EventBus()
    runner = InstantRunner(registry, bus)
    sched = Scheduler(registry, runner, bus, quota_k=1)
    # hold the first job so a deep backlog builds up behind it
    first = registry.submit(_spec("hold", duration=None))
    runner.held.add(first.job_id)
    sched.submit(first)
    jobs = [registry.submit(_spec(f"fast{i}", duration=None))
            for i in range(1500)]
    for j in jobs:
        sched.submit(j)
    assert sched.queue_depth("p", "u") == 1500
    # completing the held job cascades every queued instant job through a
    # terminal event published inside launch(); the guarded dispatch loop
    # must drain iteratively (the recursive version blows the stack) and
    # launch each job exactly once within quota.
    runner.held.clear()
    runner.finish(first.job_id)
    assert all(registry.get(j.job_id).state == JobState.FINISHED
               for j in jobs)
    assert all(c == 1 for c in runner.launch_counts.values())
    assert runner.max_concurrent == 1          # quota_k never exceeded
    assert sched.queue_depth("p", "u") == 0
    assert sched.active_count("p", "u") == 0


# -- virtual runner terminal-event contract ----------------------------
def test_virtual_runner_publishes_killed_status():
    registry, bus, runner, sched = _engine(quota_k=10)
    monitor = JobMonitor(bus)
    j = _submit(registry, sched, _spec("victim", duration=100.0))
    _submit(registry, sched, _spec("other", duration=1.0))
    sched.kill(j.job_id)
    sched.run_to_completion()
    assert monitor.status[j.job_id] == "KILLED"
    # terminal events carry the incarnation's epoch stamp so handlers
    # can drop stale ones (the job never retried, so epoch is 0)
    assert (TOPIC_CONTAINER_STATUS,
            {"job_id": j.job_id, "status": "KILLED",
             "epoch": 0}) in bus.history


def test_scheduler_metrics_surface_through_monitor_and_dashboard():
    from repro.core.engine.dashboard import scheduler_page
    cl = Cluster({"vcpu": 2.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl, quota_k=100)
    monitor = JobMonitor(bus)
    for i in range(6):
        _submit(registry, sched, _spec(f"j{i}", duration=2.0,
                                       resources={"vcpu": 1}))
    sched.run_to_completion()
    assert monitor.cluster_samples
    assert monitor.peak_utilization()["vcpu"] == pytest.approx(1.0)
    assert sched.mean_queue_wait() > 0.0       # contention produced waits
    page = scheduler_page(sched, monitor)
    assert "vcpu" in page and "mean_queue_wait" in page
    assert "utilization.vcpu" in page
    # scheduler snapshots rode the bus on their own topic
    assert any(t == TOPIC_SCHEDULER for t, _ in bus.history)
