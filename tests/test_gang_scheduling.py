"""Gang scheduling + topology-aware placement: atomic all-or-none gang
admission with node-granular packing, gang preemption as one unit,
shrink-to-k elastic resize, the transfer-cost model's interconnect
spread penalty, advance-warning reclaim checkpoints, submit-time spec
validation, and the SDK's ``gang=`` plumbing."""
import types

import pytest

from repro.core.acai import AcaiEngine
from repro.core.engine.cluster import CapacityError, Cluster
from repro.core.engine.events import EventBus
from repro.core.engine.launcher import VirtualRunner
from repro.core.engine.lifecycle import JobState
from repro.core.engine.pipeline import Pipeline
from repro.core.engine.placement import Placement, TransferCostModel
from repro.core.engine.registry import GangSpec, JobRegistry, JobSpec
from repro.core.engine.scheduler import Scheduler, validate_spec
from repro.core.provision.pricing import default_catalog
from repro.train.fault import JobPreempted, gang_resize_hook


def _spec(name="j", user="u", duration=10.0, **kw):
    return JobSpec(name=name, project="p", user=user, duration=duration,
                   **kw)


def _gpu_pool(nodes=2, node_gpus=8.0, **kw):
    return Cluster({"gpu": node_gpus * nodes}, {"gpu": 0.0}, name="gpu",
                   node_shape={"gpu": node_gpus}, **kw)


def _engine(pools, quota_k=100, **kw):
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus, **{
        k: kw.pop(k) for k in ("checkpoint_interval", "pricing")
        if k in kw})
    sched = Scheduler(registry, runner, bus, quota_k=quota_k,
                      placement=Placement(pools), **kw)
    return registry, bus, runner, sched


def _submit(registry, sched, spec):
    job = registry.submit(spec)
    sched.submit(job)
    return job


# -- cluster: node-granular gang accounting -----------------------------
def test_reserve_gang_is_atomic_and_releases_whole():
    cl = _gpu_pool(nodes=2)
    agg = cl.reserve_gang("g", {"gpu": 4.0}, 3)
    assert agg == {"gpu": 12.0}
    assert cl.used["gpu"] == 12.0
    assert cl.gang_of("g") == ({"gpu": 4.0}, 3)
    # idempotent per job id (a dispatch retry must not double-charge)
    assert cl.reserve_gang("g", {"gpu": 4.0}, 3) == agg
    assert cl.used["gpu"] == 12.0
    # release-all mirrors reserve-all: every pod and node slot comes back
    assert cl.release("g") == agg
    assert cl.used["gpu"] == 0.0
    assert cl.gang_of("g") is None
    assert all(f == {"gpu": 8.0} for f in cl._node_free)


def test_failed_gang_pack_leaves_zero_partial_hold():
    """Aggregate fits but the pods cannot all node-pack: the reserve must
    raise with the books untouched — never a partial gang hold."""
    cl = _gpu_pool(nodes=2)
    # a single job on a node-shaped pool routes through the node packer
    cl.reserve_gang("blocker", {"gpu": 5.0}, 1)  # node 0: 3, node 1: 8
    before = dict(cl.used)
    # 2 pods x 6 gpu = 12 <= 11 free? no: 12 > 11 -> aggregate reject
    with pytest.raises(CapacityError):
        cl.reserve_gang("g1", {"gpu": 6.0}, 2)
    # 2 pods x 5 gpu = 10 <= 11 free, but only node 1 fits a 5-gpu pod
    with pytest.raises(CapacityError, match="pack"):
        cl.reserve_gang("g2", {"gpu": 5.0}, 2)
    assert cl.used == before
    assert set(cl.gang_reservations()) == {"blocker"}
    assert "g1" not in cl._held and "g2" not in cl._held


def test_can_pack_is_node_granular_not_aggregate():
    cl = _gpu_pool(nodes=2)
    cl.reserve_gang("blocker", {"gpu": 5.0}, 1)
    assert cl.can_pack({"gpu": 5.0}, 1)
    assert not cl.can_pack({"gpu": 5.0}, 2)    # aggregate 10 <= 11 free
    assert cl.can_pack({"gpu": 3.0}, 3)        # 3+3 on node 1, 3 on node 0


def test_shrink_gang_hold_frees_trailing_pods_and_node_slots():
    cl = _gpu_pool(nodes=2)
    cl.reserve_gang("g", {"gpu": 4.0}, 4)      # 2 pods per node
    assert cl.used["gpu"] == 16.0
    freed = cl.shrink_gang_hold("g", 1)
    assert freed == {"gpu": 12.0}
    assert cl.used["gpu"] == 4.0
    assert cl.held("g") == {"gpu": 4.0}
    assert cl.gang_of("g") == ({"gpu": 4.0}, 1)
    # three node slots came back: a 3-pod gang packs again
    assert cl.can_pack({"gpu": 4.0}, 3)
    with pytest.raises(ValueError):
        cl.shrink_gang_hold("g", 0)            # never to zero pods
    with pytest.raises(KeyError):
        cl.shrink_gang_hold("nope", 1)


# -- scheduler: all-or-none admission -----------------------------------
def test_gang_waits_whole_and_holds_nothing_while_queued():
    """A gang that cannot pack NOW queues as one unit with zero capacity
    held, then launches whole when the blocker drains."""
    cl = _gpu_pool(nodes=2)
    registry, bus, runner, sched = _engine({"gpu": cl})
    blocker = _submit(registry, sched,
                      _spec("blocker", duration=10.0,
                            resources={"gpu": 5.0}))
    gang = _submit(registry, sched,
                   _spec("gang", duration=5.0, resources={"gpu": 5.0},
                         gang=GangSpec(n_pods=2)))
    # aggregate (10) fits the 11 free, but node 0 cannot host a 5-gpu pod
    assert registry.get(gang.job_id).state == JobState.QUEUED
    assert cl.used["gpu"] == 5.0               # zero partial-gang hold
    assert gang.job_id not in cl.gang_reservations()
    sched.run_to_completion()
    assert registry.get(blocker.job_id).state == JobState.FINISHED
    assert registry.get(gang.job_id).state == JobState.FINISHED
    assert cl.used["gpu"] == 0.0


def test_gang_launch_reserves_aggregate_and_stamps_width():
    cl = _gpu_pool(nodes=2)
    registry, bus, runner, sched = _engine({"gpu": cl})
    gang = _submit(registry, sched,
                   _spec("gang", duration=5.0, resources={"gpu": 4.0},
                         gang=GangSpec(n_pods=3)))
    assert registry.get(gang.job_id).state == JobState.RUNNING
    assert gang.gang_pods == 3
    assert cl.held(gang.job_id) == {"gpu": 12.0}
    assert cl.gang_of(gang.job_id) == ({"gpu": 4.0}, 3)


def test_gang_too_wide_for_pool_fails_fast_at_submit():
    cl = _gpu_pool(nodes=2)
    registry, bus, runner, sched = _engine({"gpu": cl})
    # per-pod overflows a node: no pool can EVER pack it
    wide = _submit(registry, sched,
                   _spec("wide", resources={"gpu": 9.0},
                         gang=GangSpec(n_pods=1)))
    assert registry.get(wide.job_id).state == JobState.FAILED
    # aggregate overflows the pool
    many = _submit(registry, sched,
                   _spec("many", resources={"gpu": 4.0},
                         gang=GangSpec(n_pods=8)))
    assert registry.get(many.job_id).state == JobState.FAILED


# -- gang preemption: one unit, one epoch bump --------------------------
def test_gang_preempts_whole_with_single_epoch_bump():
    cl = _gpu_pool(nodes=2)
    registry, bus, runner, sched = _engine(
        {"gpu": cl}, preemption=True, checkpoint_interval=2.0)
    gang = _submit(registry, sched,
                   _spec("gang", duration=10.0, resources={"gpu": 4.0},
                         gang=GangSpec(n_pods=4)))
    assert registry.get(gang.job_id).state == JobState.RUNNING
    assert cl.used["gpu"] == 16.0
    runner.advance_to(5.0)
    epoch0 = gang.epoch
    assert sched.preempt(gang.job_id)
    # the WHOLE gang released in ONE preemption, then relaunched whole by
    # the trailing dispatch: exactly one fresh 4-pod hold (16, not 32 —
    # a lingering pod would double-charge), and ONE epoch bump for all
    # 4 pods, not one per pod
    assert gang.epoch == epoch0 + 1
    assert sched.stats["preempted"] == 1
    assert runner.preempt_stats["preemptions"] == 1
    assert registry.get(gang.job_id).state == JobState.RUNNING
    assert cl.used["gpu"] == 16.0
    assert cl.gang_reservations() == {gang.job_id: ({"gpu": 4.0}, 4)}
    sched.run_to_completion()
    assert registry.get(gang.job_id).state == JobState.FINISHED
    # checkpoint-resume: at most one interval of gang work re-ran
    assert runner.preempt_stats["max_lost_s"] <= 2.0 + 1e-9


# -- elastic shrink-to-k ------------------------------------------------
def test_shrink_gang_frees_capacity_and_repaces_without_requeue():
    cl = _gpu_pool(nodes=2)
    registry, bus, runner, sched = _engine({"gpu": cl})
    gang = _submit(registry, sched,
                   _spec("gang", duration=100.0, resources={"gpu": 4.0},
                         gang=GangSpec(n_pods=4, min_pods=2)))
    runner.advance_to(50.0)
    epoch0 = gang.epoch
    assert sched.shrink_gang(gang.job_id, 2)
    # half the work done at width 4; the rest runs at old/k = 2x slower
    assert runner.expected_end(gang.job_id) == pytest.approx(150.0)
    assert gang.gang_pods == 2
    assert gang.epoch == epoch0                # no requeue, no epoch bump
    assert registry.get(gang.job_id).state == JobState.RUNNING
    assert cl.held(gang.job_id) == {"gpu": 8.0}
    assert cl.can_pack({"gpu": 8.0}, 1)        # a full node came back
    assert sched.stats["gang_shrunk"] == 1
    sched.run_to_completion()
    assert registry.get(gang.job_id).state == JobState.FINISHED
    assert runner.now == pytest.approx(150.0)


def test_shrink_gang_rejects_non_resizable_and_bad_widths():
    cl = _gpu_pool(nodes=2)
    registry, bus, runner, sched = _engine({"gpu": cl})
    fixed = _submit(registry, sched,
                    _spec("fixed", duration=50.0, resources={"gpu": 2.0},
                          gang=GangSpec(n_pods=2)))          # min_pods=0
    rsz = _submit(registry, sched,
                  _spec("rsz", duration=50.0, resources={"gpu": 2.0},
                        gang=GangSpec(n_pods=4, min_pods=2)))
    assert not sched.shrink_gang(fixed.job_id, 1)
    assert not sched.shrink_gang(rsz.job_id, 1)    # below min_pods floor
    assert not sched.shrink_gang(rsz.job_id, 4)    # not a shrink
    assert rsz.gang_pods == 4                      # untouched
    sched.run_to_completion()


def test_pool_shrink_resizes_gangs_before_preempting():
    """An elastic shrink's drain must prefer shrinking a resizable gang
    in place over evicting jobs (satellite: softened drains)."""
    cl = _gpu_pool(nodes=2)
    registry, bus, runner, sched = _engine(
        {"gpu": cl}, preemption=True, checkpoint_interval=5.0)
    gang = _submit(registry, sched,
                   _spec("gang", duration=100.0, resources={"gpu": 8.0},
                         gang=GangSpec(n_pods=2, min_pods=1)))
    assert cl.used["gpu"] == 16.0
    sched.resize_pool("gpu", {"gpu": 8.0})     # drop to one node
    assert gang.gang_pods == 1                 # shrunk, not preempted
    assert registry.get(gang.job_id).state == JobState.RUNNING
    assert cl.used["gpu"] == 8.0
    assert sched.stats["gang_shrunk"] == 1
    assert sched.stats["preempted"] == 0
    sched.run_to_completion()
    assert registry.get(gang.job_id).state == JobState.FINISHED


# -- reclaim with advance warning (satellite: grace-window checkpoints) --
def _reclaim_setup():
    cl = Cluster({"vcpu": 8.0}, {"vcpu": 0.0}, name="spot", spot=True)
    registry, bus, runner, sched = _engine(
        {"spot": cl}, preemption=True, checkpoint_interval=30.0)
    job = _submit(registry, sched,
                  _spec("victim", duration=100.0,
                        resources={"vcpu": 8.0}))
    assert registry.get(job.job_id).state == JobState.RUNNING
    runner.advance_to(47.0)                    # 17s past the checkpoint
    return registry, runner, sched, job


def test_reclaim_warning_banks_exact_progress_lost_work_zero():
    registry, runner, sched, job = _reclaim_setup()
    assert sched.reclaim("spot", warning=5.0) == [job.job_id]
    # the grace-window checkpoint landed first: nothing is lost
    assert runner.preempt_stats["lost_work_s"] == pytest.approx(0.0)
    sched.run_to_completion()
    assert registry.get(job.job_id).state == JobState.FINISHED
    assert runner.now == pytest.approx(100.0)  # no re-run work at all


def test_reclaim_without_warning_loses_at_most_one_interval():
    """Regression pin for the checkpoint-interval bound: a no-warning
    reclaim rolls back to the interval grid — lost work is positive but
    never exceeds one checkpoint interval."""
    registry, runner, sched, job = _reclaim_setup()
    assert sched.reclaim("spot") == [job.job_id]
    lost = runner.preempt_stats["lost_work_s"]
    assert 0.0 < lost <= 30.0 + 1e-9
    assert lost == pytest.approx(17.0)         # 47 - floor(47/30)*30
    sched.run_to_completion()
    assert registry.get(job.job_id).state == JobState.FINISHED
    assert runner.now == pytest.approx(100.0 + lost)


# -- placement: transfer-cost model -------------------------------------
def test_transfer_cost_model_rates_and_pair_overrides():
    m = TransferCostModel(cost_per_gb=2.0,
                          pair_cost_per_gb={("a", "b"): 0.5})
    assert m.transfer_cost("a", "a", 1e9) == 0.0
    assert m.transfer_cost("a", "b", 1e9) == 0.5
    assert m.transfer_cost("b", "a", 1e9) == 2.0
    assert m.cheapest_transfer({"a", "b"}, "a", 1e9) == 0.0   # local parent
    assert m.cheapest_transfer({"b"}, "a", 2e9) == 4.0


def test_close_gang_prefers_island_pool_over_cheaper_spread():
    """A close-topology gang pays the interconnect spread penalty on a
    pool that splits it across islands — the penalty must beat a plain
    price advantage, and vanish with transfer_costs=None (legacy)."""
    whole = Cluster({"gpu": 64.0}, {"gpu": 0.0}, name="whole",
                    node_shape={"gpu": 32.0}, close_gang_pods=8)
    split = Cluster({"gpu": 128.0}, {"gpu": 0.0}, name="split",
                    node_shape={"gpu": 32.0}, close_gang_pods=2)
    spec = _spec("train", resources={"gpu": 4.0},
                 gang=GangSpec(n_pods=8, topology="close"))
    aware = Placement(
        {"whole": whole, "split": split},
        transfer_costs=TransferCostModel(interconnect_weight=4.0))
    opts = aware.eligible(spec)
    assert opts["whole"].charge == {"gpu": 32.0} and opts["whole"].pods == 8
    assert aware.rank(spec, opts)[0] == "whole"
    # without the model the bigger (lower normalized score) pool wins
    oblivious = Placement({"whole": whole, "split": split})
    assert oblivious.rank(spec, oblivious.eligible(spec))[0] == "split"


def test_offpool_child_pays_modelled_transfer_of_its_input_bytes():
    a = Cluster({"vcpu": 8.0}, {"vcpu": 0.0}, name="a")
    b = Cluster({"vcpu": 80.0}, {"vcpu": 0.0}, name="b")
    pl = Placement({"a": a, "b": b},
                   transfer_costs=TransferCostModel(cost_per_gb=1.0))
    spec = _spec("child", duration=10.0, resources={"vcpu": 4.0})
    spec.input_bytes = 50e9
    # parent ran on "a": staying local dodges a 50-unit transfer that
    # dwarfs b's normalized-capacity advantage
    assert pl.rank(spec, pl.eligible(spec), {"a"})[0] == "a"
    # with no parents the cheaper pool wins again
    assert pl.rank(spec, pl.eligible(spec))[0] == "b"


# -- submit-time validation (satellite: reject malformed specs) ---------
def test_validate_spec_rejects_zero_and_negative_dims():
    with pytest.raises(ValueError, match="must be a positive number"):
        validate_spec(_spec(resources={"gpu": 0}))
    with pytest.raises(ValueError, match="mem_mb"):
        validate_spec(_spec(resources={"vcpu": 1, "mem_mb": -512}))
    with pytest.raises(ValueError, match="pool_resources"):
        validate_spec(_spec(pool_resources={"tpu": {"chips": -8}}))
    with pytest.raises(ValueError, match="gang.per_pod_resources"):
        validate_spec(_spec(gang=GangSpec(
            n_pods=2, per_pod_resources={"gpu": 0.0})))
    validate_spec(_spec(resources={"gpu": 4}))            # sane: no raise


def test_validate_spec_rejects_malformed_gangs():
    with pytest.raises(ValueError, match="n_pods"):
        validate_spec(_spec(gang=GangSpec(n_pods=0)))
    with pytest.raises(ValueError, match="min_pods"):
        validate_spec(_spec(gang=GangSpec(n_pods=4, min_pods=5)))
    with pytest.raises(ValueError, match="topology"):
        validate_spec(_spec(gang=GangSpec(n_pods=4, topology="ring")))


def test_scheduler_submit_raises_before_any_state_change():
    cl = _gpu_pool(nodes=2)
    registry, bus, runner, sched = _engine({"gpu": cl})
    bad = registry.submit(_spec("bad", resources={"gpu": -1}))
    with pytest.raises(ValueError, match="positive"):
        sched.submit(bad)
    assert sched.queue_depth("p", "u") == 0    # never entered a queue


def test_engine_submit_rejects_unknown_pool_names():
    eng = AcaiEngine(pricing=default_catalog(), virtual=True,
                     cluster_nodes={"cpu": 2, "tpu": 1}, quota_k=10)
    with pytest.raises(ValueError, match="unknown pool"):
        eng.submit(_spec("pinned", resources={"vcpu": 1}, pool="gpuz"))
    with pytest.raises(ValueError, match="gpuz"):
        eng.submit(_spec("menu", pool_resources={"gpuz": {"gpu": 1}}))
    # a known pool still sails through
    h = eng.submit(_spec("ok", duration=0.5, resources={"vcpu": 1},
                         pool="cpu"))
    assert h.wait() == JobState.FINISHED


# -- SDK plumbing: Pipeline gang= ---------------------------------------
def test_pipeline_stage_and_map_stamp_gang_specs():
    pipe = Pipeline(None, name="t", submit=lambda spec: None)
    st = pipe.stage(_spec("train", resources={"gpu": 4.0}), gang=8)
    assert st.spec.gang == GangSpec(n_pods=8)
    custom = GangSpec(n_pods=4, min_pods=2, topology="close")
    sts = pipe.map(lambda p: _spec(f"s{p['i']}", resources={"gpu": 2.0}),
                   {"i": [0, 1, 2]}, gang=custom)
    assert all(s.spec.gang == custom for s in sts)
    plain = pipe.stage(_spec("eval"))
    assert plain.spec.gang is None


def test_pipeline_gang_runs_end_to_end_through_the_engine():
    eng = AcaiEngine(pricing=default_catalog(), virtual=True,
                     cluster_nodes={"cpu": 2, "tpu": 1}, quota_k=10)
    pipe = eng.pipeline("gangs")
    st = pipe.stage(_spec("train", duration=1.0,
                          resources={"vcpu": 2.0}), gang=2)
    pipe.run()
    assert st.handle.wait() == JobState.FINISHED
    # the gang billed at width 2: cost doubles a 1-pod twin's
    twin = eng.submit(_spec("solo", duration=1.0,
                            resources={"vcpu": 2.0}))
    assert twin.wait() == JobState.FINISHED
    assert st.handle.job.cost == pytest.approx(2 * twin.job.cost)


# -- train-side resize hook ---------------------------------------------
def test_gang_resize_hook_fires_once_per_shrink_and_stays_internal():
    job = types.SimpleNamespace(job_id="j-1", gang_pods=8)
    hook = gang_resize_hook(job)
    hook(1)                                    # steady width: no raise
    job.gang_pods = 4
    with pytest.raises(JobPreempted) as ei:
        hook(2)
    assert "4 pods" in str(ei.value)
    assert not getattr(ei.value, "external", False)   # in-process re-mesh
    hook(3)                                    # acted on: no re-raise
    job.gang_pods = 2
    with pytest.raises(JobPreempted):
        hook(4)


def test_gang_resize_hook_ignores_non_gang_jobs():
    job = types.SimpleNamespace(job_id="j-2", gang_pods=None)
    hook = gang_resize_hook(job)
    for step in range(3):
        hook(step)                             # never raises
