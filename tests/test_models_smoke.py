"""Per-arch reduced-config smoke tests: one forward/train step on CPU,
asserting output shapes and no NaNs; plus decode-step state threading."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch, list_archs
from repro.models import model as M
from repro.models import transformer as T

ARCHS = ["qwen3-32b", "qwen3-8b", "mistral-nemo-12b", "olmo-1b",
         "olmoe-1b-7b", "llama4-scout-17b-a16e", "rwkv6-7b",
         "llama-3.2-vision-11b", "zamba2-7b", "musicgen-large"]


def _batch(cfg, b=2, s=32, key=0):
    k = jax.random.PRNGKey(key)
    if cfg.n_codebooks:
        tokens = jax.random.randint(k, (b, s, cfg.n_codebooks), 0,
                                    cfg.vocab_size)
    else:
        tokens = jax.random.randint(k, (b, s), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    return {"tokens": tokens, "labels": labels}


def _vision(cfg, b=2):
    if cfg.family != "vlm":
        return None
    return jax.random.normal(jax.random.PRNGKey(7),
                             (b, cfg.n_vision_tokens, cfg.vision_dim),
                             jnp.float32).astype(jnp.bfloat16)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_arch(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    ctx = M.make_ctx(cfg, 32, "train", vision=_vision(cfg), remat="full")
    loss, metrics = M.loss_fn(params, batch, cfg, ctx)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    logits, aux, _ = M.forward(params, batch["tokens"], cfg, ctx)
    if cfg.n_codebooks:
        assert logits.shape == (2, 32, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step(arch):
    cfg = get_arch(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    ctx = M.make_ctx(cfg, 32, "train", vision=_vision(cfg))

    def lf(p):
        return M.loss_fn(p, batch, cfg, ctx)[0]

    loss, grads = jax.value_and_grad(lf)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)
    # at least some gradient is non-zero
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_arch(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, buf = 2, 16
    vision = _vision(cfg, b)
    states = T.init_decode_state(cfg, b, buf, vision=vision, params=params)
    cache_len = jnp.zeros((b,), jnp.int32)
    if cfg.n_codebooks:
        tok = jnp.ones((b, 1, cfg.n_codebooks), jnp.int32)
    else:
        tok = jnp.ones((b, 1), jnp.int32)
    for _ in range(3):
        ctx = M.make_ctx(cfg, buf, "decode", vision=vision,
                         cache_len=cache_len)
        logits, states = M.decode_step(params, tok, states, cache_len, cfg,
                                       ctx)
        cache_len = cache_len + 1
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any()), arch
    if cfg.n_codebooks:
        assert logits.shape == (b, 1, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, 1, cfg.vocab_size)


def test_all_archs_registered():
    assert set(ARCHS) <= set(list_archs())


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_positive(arch):
    cfg = get_arch(arch)
    n = cfg.n_params()
    na = cfg.n_active_params()
    assert n > 0 and na > 0 and na <= n
