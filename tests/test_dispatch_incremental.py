"""Incremental dispatch core: decision-equivalence replay against golden
traces recorded from the pre-refactor scheduler, kill-under-load
complexity/leak regressions, the live min-charge saturation bound, and
the bounded event-bus history."""
import json
from pathlib import Path

from benchmarks.bench_scheduler import decision_trace
from repro.core.engine.cluster import Cluster
from repro.core.engine.events import EventBus, TOPIC_CONTAINER_STATUS
from repro.core.engine.launcher import VirtualRunner
from repro.core.engine.lifecycle import JobState
from repro.core.engine.registry import JobRegistry, JobSpec
from repro.core.engine.scheduler import Scheduler

DATA = Path(__file__).parent / "data"


def _golden(name: str) -> list:
    with open(DATA / f"golden_trace_{name}.json") as f:
        return json.load(f)


# -- decision-equivalence replay (the tentpole's proof) ------------------
def test_fair_backfill_trace_matches_pre_refactor_golden():
    """500-job fixed-seed Poisson fleet with periodic kills under
    fair+backfill: launch order and pool assignment must be bit-identical
    to the trace recorded before the incremental dispatch core landed."""
    got = decision_trace(500, 7, policy="fair", backfill=True,
                         kill_every=23)
    assert got == _golden("policy_fair")


def test_fifo_trace_matches_pre_refactor_golden():
    got = decision_trace(300, 11, policy="fifo", backfill=False)
    assert got == _golden("policy_fifo")


def test_heterogeneous_placement_trace_matches_pre_refactor_golden():
    """Multi-pool fleet through profiler-fed placement: pool assignments
    (not just launch order) must replay exactly."""
    got = decision_trace(400, 3, hetero=True, quota_k=64)
    assert got == _golden("hetero")


def test_hetero_trace_unchanged_with_gang_machinery_compiled_in():
    """The gang layers (TransferCostModel scoring path, gang-aware
    eligibility, atomic-reserve dispatch records) compiled in but unused
    — zero transfer rates, no gangs, no cross-pool filesets — must not
    perturb a single decision: the hetero golden replays bit-identically
    through the transfer-cost scoring branch."""
    from repro.core.engine.placement import TransferCostModel
    got = decision_trace(400, 3, hetero=True, quota_k=64,
                         transfer_costs=TransferCostModel(cost_per_gb=0.0))
    assert got == _golden("hetero")


def test_preemption_enabled_trace_matches_golden():
    """The preemption-policy golden (recorded when the feature landed):
    starved high-priority heads preempt victims whose relaunches appear
    as duplicate trace entries — victim selection, checkpoint-resume
    scheduling and kill interleaving are all pinned."""
    got = decision_trace(400, 7, policy="fair", backfill=True,
                         preemption=True, starvation_threshold=60.0,
                         checkpoint_interval=30.0, priority_every=7,
                         kill_every=31)
    golden = _golden("preempt")
    assert got == golden
    # the trace really exercises preemption: relaunches duplicate names
    assert len(golden) > len({name for name, _ in golden})


# -- kill under load: O(1) amortized, no tombstone leaks -----------------
def _engine(cluster=None, quota_k=100):
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus)
    sched = Scheduler(registry, runner, bus, quota_k=quota_k,
                      cluster=cluster)
    return registry, bus, runner, sched


def _spec(name, duration=1.0, resources=None, user="u"):
    return JobSpec(name=name, project="p", user=user, duration=duration,
                   resources=resources or {})


def test_kill_deep_in_queue_is_cheap_and_leaves_no_tombstones():
    """Killing jobs buried deep behind a blocked head must not rescan the
    queue per kill (the old ``deque.remove``), and the tombstones it
    leaves in the tail must be compacted away rather than accumulating
    for the life of the engine."""
    cl = Cluster({"vcpu": 1.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl, quota_k=1000)
    hog = registry.submit(_spec("hog", duration=1e6,
                                resources={"vcpu": 1}))
    sched.submit(hog)
    victims = []
    for i in range(2000):
        j = registry.submit(_spec(f"v{i}", duration=1.0,
                                  resources={"vcpu": 1}))
        sched.submit(j)
        victims.append(j.job_id)
    assert sched.queue_depth("p", "u") == 2000

    # kill every other victim, deepest first — the worst case for a
    # deque scan. Tombstoning makes each kill O(1); the compaction
    # invariant keeps dead entries from outnumbering the living.
    for jid in victims[::-2]:
        sched.kill(jid)
    live = sched.queue_depth("p", "u")
    tail = len(sched._queues[("p", "u")])
    assert tail <= live + max(8, live), (tail, live)
    sched.run_to_completion()
    assert sched.queue_depth("p", "u") == 0
    # every queue structure drained: no tombstone survives the run
    assert sum(len(q) for q in sched._queues.values()) == 0
    for w in sched._qwin.values():
        assert not w.rows and not w.ids and not w.pdur_of
        assert not any(w.pdurs.values())
    assert not sched._queued_set
    # per-job bookkeeping fully reclaimed (no leak over engine lifetime)
    for cache in (sched._prio_of, sched._opts_of, sched._rank_of,
                  sched._dinfo, sched._job_of, sched._seq_of,
                  sched._started_at, sched._queued_at, sched._end_key):
        assert not cache, cache
    assert all(registry.get(j).state in (JobState.FINISHED,
                                         JobState.KILLED)
               for j in victims)


def test_killed_queued_job_publishes_terminal_and_frees_nothing():
    cl = Cluster({"vcpu": 1.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl, quota_k=10)
    a = registry.submit(_spec("a", duration=5.0, resources={"vcpu": 1}))
    sched.submit(a)
    b = registry.submit(_spec("b", duration=5.0, resources={"vcpu": 1}))
    sched.submit(b)
    seen = []
    bus.subscribe(TOPIC_CONTAINER_STATUS,
                  lambda m: seen.append((m["job_id"], m["status"])))
    sched.kill(b.job_id)
    assert (b.job_id, "KILLED") in seen
    assert cl.used["vcpu"] == 1.0          # only the running job holds it
    sched.run_to_completion()
    assert cl.used["vcpu"] == 0.0


# -- live min-charge saturation bound ------------------------------------
def test_min_charge_bound_recovers_after_small_job_drains():
    """The old bound only ever decreased at submit: once a tiny job
    completed, ``_saturated()`` kept judging the pool by its charge
    forever and the short-circuit mis-fired (never True with a big-job
    backlog that provably cannot fit). The live bound must tighten."""
    cl = Cluster({"vcpu": 4.0}, {"vcpu": 0.5})
    registry, bus, runner, sched = _engine(cluster=cl, quota_k=1)
    hog = registry.submit(_spec("hog", duration=100.0,
                                resources={"vcpu": 3}))
    sched.submit(hog)                   # runs: 1 vcpu left free
    # same user => quota-held even though its 1 vcpu would fit
    tiny = registry.submit(_spec("tiny", duration=1.0,
                                 resources={"vcpu": 1}))
    sched.submit(tiny)
    # another user's big jobs can never fit next to the hog (3 + 2 > 4)
    bigs = []
    for i in range(3):
        j = registry.submit(_spec(f"big{i}", duration=10.0,
                                  resources={"vcpu": 2}, user="other"))
        sched.submit(j)
        bigs.append(j.job_id)
    assert registry.get(tiny.job_id).state == JobState.QUEUED
    assert not sched._saturated()       # tiny is live: 1 vcpu would fit
    sched.kill(tiny.job_id)             # tiny leaves the queue
    # live bound: smallest queued charge is now 2 vcpu > 1 free. The old
    # write-only bound kept tiny's 1 vcpu forever and never short-circuited.
    assert sched._saturated()
    sched.run_to_completion()
    assert all(registry.get(j).state == JobState.FINISHED
               for j in [hog.job_id] + bigs)


# -- bounded event-bus history -------------------------------------------
def test_event_bus_history_is_a_bounded_ring():
    bus = EventBus(history_limit=8)
    for i in range(20):
        bus.publish("t", {"i": i})
    assert len(bus.history) == 8
    assert [m["i"] for _, m in bus.history] == list(range(12, 20))
    # membership (the idiom tests use) still works on the ring
    assert ("t", {"i": 19}) in bus.history
    assert ("t", {"i": 0}) not in bus.history


def test_event_bus_single_copy_shared_with_subscribers():
    bus = EventBus()
    got = []
    bus.subscribe("t", got.append)
    src = {"a": 1}
    bus.publish("t", src)
    src["a"] = 2                        # caller mutation after publish
    assert got[0] == {"a": 1}           # subscriber saw the snapshot
    assert bus.history[-1][1] is got[0]  # one copy, shared with history


# -- snapshot coalescing --------------------------------------------------
def test_snapshot_interval_coalesces_metrics():
    cl = Cluster({"vcpu": 2.0}, {"vcpu": 0.5})
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus)
    dense = Scheduler(registry, runner, bus, quota_k=100, cluster=cl)
    for i in range(6):
        j = registry.submit(_spec(f"j{i}", duration=2.0,
                                  resources={"vcpu": 1}))
        dense.submit(j)
    dense.run_to_completion()
    assert dense.stats["snapshots"] > 1
    assert dense.stats["snapshots_skipped"] == 0

    registry2 = JobRegistry()
    bus2 = EventBus()
    runner2 = VirtualRunner(registry2, bus2)
    coarse = Scheduler(registry2, runner2, bus2, quota_k=100,
                       cluster=Cluster({"vcpu": 2.0}, {"vcpu": 0.5}),
                       snapshot_interval=1e9)
    for i in range(6):
        j = registry2.submit(JobSpec(name=f"j{i}", project="p", user="u",
                                     duration=2.0,
                                     resources={"vcpu": 1}))
        coarse.submit(j)
    coarse.run_to_completion()
    assert coarse.stats["snapshots"] == 1      # first publish only
    assert coarse.stats["snapshots_skipped"] > 0
