"""Placement layer: pool eligibility, profiler-fed cost/speed scoring,
dataflow-locality co-placement, per-pool EASY backfill, fail-fast
infeasibility, and the catalog-aware auto-provisioner."""
import pytest

from repro.core.acai import AcaiEngine
from repro.core.engine.cluster import Cluster
from repro.core.engine.dashboard import scheduler_page
from repro.core.engine.events import EventBus, TOPIC_SCHEDULER
from repro.core.engine.launcher import VirtualRunner
from repro.core.engine.lifecycle import JobState
from repro.core.engine.monitor import JobMonitor
from repro.core.engine.placement import Placement
from repro.core.engine.registry import JobRegistry, JobSpec
from repro.core.engine.scheduler import Scheduler
from repro.core.provision.autoprovision import AutoProvisioner
from repro.core.provision.pricing import (CPU_PRICING, TPU_PRICING,
                                          default_catalog)
from repro.core.provision.profiler import CommandTemplate, Profiler


def _spec(name="j", user="u", duration=1.0, **kw):
    return JobSpec(name=name, project="p", user=user, duration=duration,
                   **kw)


def _hetero_pools():
    return {"cpu": Cluster({"vcpu": 8.0, "mem_mb": 8192.0},
                           {"vcpu": 0.5, "mem_mb": 512.0}, name="cpu"),
            "tpu": Cluster({"chips": 16.0}, {"chips": 8.0}, name="tpu")}


def _engine(placement, quota_k=100, policy="fair", backfill=True,
            oracle=None):
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus, oracle=oracle)
    sched = Scheduler(registry, runner, bus, quota_k=quota_k,
                      placement=placement, policy=policy, backfill=backfill)
    return registry, bus, runner, sched


def _submit(registry, sched, spec):
    job = registry.submit(spec)
    sched.submit(job)
    return job


def _track_starts(runner):
    starts = {}
    orig = runner.launch

    def launch(job):
        starts[job.job_id] = runner.now
        orig(job)
    runner.launch = launch
    return starts


# -- eligibility -------------------------------------------------------
def test_eligibility_resource_dims_select_family():
    pl = Placement(_hetero_pools())
    # plain resources tried on every pool; unknown dims reject
    assert set(pl.eligible(_spec(resources={"vcpu": 2}))) == {"cpu"}
    assert set(pl.eligible(_spec(resources={"chips": 8}))) == {"tpu"}
    # an explicit per-pool menu is authoritative
    both = _spec(pool_resources={"cpu": {"vcpu": 2.0},
                                 "tpu": {"chips": 8.0}})
    assert set(pl.eligible(both)) == {"cpu", "tpu"}
    only = _spec(pool_resources={"tpu": {"chips": 8.0}})
    assert set(pl.eligible(only)) == {"tpu"}
    # pool pin restricts further
    pinned = _spec(resources={"vcpu": 2}, pool="tpu")
    assert pl.eligible(pinned) == {}


# -- profiler-fed pool selection ---------------------------------------
def _flex_spec(name="flex", work=100.0, duration=1.0, **kw):
    return _spec(name, duration=duration, template="work",
                 args={"work": work},
                 pool_resources={"cpu": {"vcpu": 2.0, "mem_mb": 512.0},
                                 "tpu": {"chips": 8.0}}, **kw)


def _fit_pool_models():
    """cpu model: runtime = work; tpu model: runtime = work / 4."""
    prof = Profiler(engine=None)
    works = [10.0, 50.0, 100.0, 400.0]
    cpu_t = CommandTemplate("work@cpu", {"work": works},
                            {"vcpu": [0.5, 2.0], "mem_mb": [512.0, 2048.0]})
    grid = cpu_t.grid()
    prof.fit_offline(cpu_t, grid, [c["work"] for c in grid])
    tpu_t = CommandTemplate("work@tpu", {"work": works},
                            {"chips": [8.0, 16.0]})
    grid = tpu_t.grid()
    prof.fit_offline(tpu_t, grid, [c["work"] / 4.0 for c in grid])
    return prof


def test_pool_selection_follows_profiler_predictions():
    """objective='runtime' sends the job to the pool the profiler says is
    faster; flipping the models flips the pool."""
    pl = Placement(_hetero_pools(), objective="runtime")
    pl.use_profiler(_fit_pool_models())
    registry, bus, runner, sched = _engine(pl)
    j = _submit(registry, sched, _flex_spec())
    assert registry.get(j.job_id).pool == "tpu"   # 4x faster there
    # flipped predictor: cpu now predicted faster
    pl2 = Placement(_hetero_pools(), objective="runtime",
                    predictor=lambda spec, pool, res:
                        1.0 if pool == "cpu" else 50.0)
    registry2, _, _, sched2 = _engine(pl2)
    j2 = _submit(registry2, sched2, _flex_spec())
    assert registry2.get(j2.job_id).pool == "cpu"


def test_cost_objective_uses_pool_pricing():
    """With objective='cost', the expensive-but-fast pool loses when the
    predicted runtime saving does not offset its price."""
    catalog = {"cpu": CPU_PRICING, "tpu": TPU_PRICING}
    pl = Placement(_hetero_pools(), pricing=catalog, objective="cost")
    pl.use_profiler(_fit_pool_models())
    registry, bus, runner, sched = _engine(pl)
    # work=100s: cpu cost ~ 100s * ~0.07/hr vs tpu 25s * ~6.6/hr
    j = _submit(registry, sched, _flex_spec(work=100.0))
    job = registry.get(j.job_id)
    assert job.pool == "cpu"
    assert job.state == JobState.RUNNING


# -- dataflow locality -------------------------------------------------
def test_locality_coplaces_child_with_parent_pool():
    """Two symmetric pools: the child of a stage that ran on pool 'b' is
    co-placed there (locality discount breaks the tie)."""
    pools = {"a": Cluster({"slot": 4.0}, {"slot": 1.0}, name="a"),
             "b": Cluster({"slot": 4.0}, {"slot": 1.0}, name="b")}
    registry, bus, runner, sched = _engine(Placement(pools))
    parent = _submit(registry, sched, _spec(
        "parent", pool="b", resources={"slot": 1}))
    child_spec = _spec("child", pool_resources={"a": {"slot": 1.0},
                                                "b": {"slot": 1.0}})
    child_spec.depends_on = [parent.job_id]
    child = _submit(registry, sched, child_spec)
    sched.run_to_completion()
    assert registry.get(parent.job_id).pool == "b"
    assert registry.get(child.job_id).pool == "b"
    assert registry.get(child.job_id).state == JobState.FINISHED


def test_without_parents_tie_breaks_deterministically():
    pools = {"a": Cluster({"slot": 4.0}, {"slot": 1.0}, name="a"),
             "b": Cluster({"slot": 4.0}, {"slot": 1.0}, name="b")}
    registry, bus, runner, sched = _engine(Placement(pools))
    j = _submit(registry, sched, _spec(
        "solo", pool_resources={"a": {"slot": 1.0}, "b": {"slot": 1.0}}))
    assert registry.get(j.job_id).pool == "a"     # name tie-break


# -- fail-fast infeasibility -------------------------------------------
def test_no_pool_fits_fails_fast_with_clear_error():
    registry, bus, runner, sched = _engine(Placement(_hetero_pools()))
    j = _submit(registry, sched, _spec(
        "huge", pool_resources={"cpu": {"vcpu": 64.0},
                                "tpu": {"chips": 512.0}}))
    job = registry.get(j.job_id)
    assert job.state == JobState.FAILED
    assert "exceed cluster capacity on every pool" in job.error
    assert "cpu" in job.error and "tpu" in job.error
    # dependents of the infeasible job cascade instead of hanging
    child_spec = _spec("child", resources={"vcpu": 1})
    child_spec.depends_on = [j.job_id]
    child = _submit(registry, sched, child_spec)
    assert registry.get(child.job_id).state == JobState.UPSTREAM_FAILED


def test_pin_to_unknown_pool_fails_fast():
    registry, bus, runner, sched = _engine(Placement(_hetero_pools()))
    j = _submit(registry, sched, _spec(
        "ghost", resources={"vcpu": 1}, pool="gpu"))
    job = registry.get(j.job_id)
    assert job.state == JobState.FAILED
    assert "gpu" in job.error


# -- per-pool EASY backfill --------------------------------------------
def test_backfill_is_per_pool_and_never_delays_blocked_head():
    """Pool 'a' has a blocked head with shadow t=10; a short job backfills
    into 'a', a long 'a' job must wait, and a flexible long job routes to
    pool 'b' instead of waiting — the blocked head still starts at t=10."""
    pools = {"a": Cluster({"slot": 4.0}, {"slot": 0.0}, name="a"),
             "b": Cluster({"slot": 4.0}, {"slot": 0.0}, name="b")}
    registry, bus, runner, sched = _engine(Placement(pools))
    starts = _track_starts(runner)
    _submit(registry, sched, _spec("A", duration=10.0, pool="a",
                                   resources={"slot": 3}))
    blocked = _submit(registry, sched, _spec("B", duration=5.0, pool="a",
                                             resources={"slot": 4}))
    short = _submit(registry, sched, _spec("C", duration=2.0, pool="a",
                                           resources={"slot": 1}))
    long_a = _submit(registry, sched, _spec("D", duration=50.0, pool="a",
                                            resources={"slot": 1}))
    flex = _submit(registry, sched, _spec(
        "E", duration=50.0, pool_resources={"a": {"slot": 1.0},
                                            "b": {"slot": 1.0}}))
    assert registry.get(short.job_id).state == JobState.RUNNING
    assert registry.get(long_a.job_id).state == JobState.QUEUED
    assert registry.get(flex.job_id).state == JobState.RUNNING
    assert registry.get(flex.job_id).pool == "b"    # escaped the convoy
    sched.run_to_completion()
    assert starts[blocked.job_id] == pytest.approx(10.0)  # not delayed
    assert starts[short.job_id] == pytest.approx(0.0)
    assert starts[long_a.job_id] >= 10.0
    assert starts[flex.job_id] == pytest.approx(0.0)
    assert sched.stats["backfilled"] == 1


def test_backfill_estimate_uses_candidate_pool_runtime():
    """A job that is quick generically but slow on the blocked pool must
    be sized at the POOL's runtime — admitting it on the generic estimate
    would delay the blocked head past its shadow start."""
    pools = {"a": Cluster({"slot": 4.0}, {"slot": 0.0}, name="a")}

    def oracle(job):
        return 60.0 if job.pool == "a" else 2.0   # startup tax on 'a'
    registry, bus, runner, sched = _engine(Placement(pools), oracle=oracle)
    starts = _track_starts(runner)
    _submit(registry, sched, _spec("A", duration=10.0,
                                   resources={"slot": 3}))
    blocked = _submit(registry, sched, _spec("B", duration=5.0,
                                             resources={"slot": 4}))
    tricky = _submit(registry, sched, _spec("C", duration=None,
                                            resources={"slot": 1}))
    # 60s on pool 'a' > shadow t=10 and no spare: must NOT backfill
    assert registry.get(tricky.job_id).state == JobState.QUEUED
    sched.run_to_completion()
    assert starts[blocked.job_id] == pytest.approx(10.0)  # not delayed
    assert registry.get(tricky.job_id).runtime == pytest.approx(60.0)


def test_blocked_head_on_one_pool_does_not_throttle_the_other():
    pools = {"a": Cluster({"slot": 1.0}, {"slot": 0.0}, name="a"),
             "b": Cluster({"slot": 1.0}, {"slot": 0.0}, name="b")}
    registry, bus, runner, sched = _engine(Placement(pools))
    _submit(registry, sched, _spec("hog", duration=100.0, pool="a",
                                   resources={"slot": 1}))
    _submit(registry, sched, _spec("blocked", duration=1.0, pool="a",
                                   resources={"slot": 1}))
    other = _submit(registry, sched, _spec("other", duration=1.0, pool="b",
                                           resources={"slot": 1}))
    assert registry.get(other.job_id).state == JobState.RUNNING


# -- pool-aware oracle + billing ---------------------------------------
def test_pool_dependent_oracle_and_pricing():
    """The virtual runner re-draws the duration for the pool placement
    chose, and bills through that pool's catalog entry."""
    catalog = {"cpu": CPU_PRICING, "tpu": TPU_PRICING}

    def oracle(job):
        return 40.0 if job.pool == "tpu" else 160.0
    pl = Placement(_hetero_pools(), pricing=catalog, objective="runtime",
                   predictor=lambda spec, pool, res:
                       40.0 if pool == "tpu" else 160.0)
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus, oracle=oracle, pricing=catalog)
    sched = Scheduler(registry, runner, bus, quota_k=10, placement=pl)
    j = _submit(registry, sched, _flex_spec("flex", duration=None))
    sched.run_to_completion()
    job = registry.get(j.job_id)
    assert job.pool == "tpu"
    assert job.runtime == pytest.approx(40.0)     # the tpu draw, not cpu
    assert job.cost == pytest.approx(
        TPU_PRICING.job_cost({"chips": 8.0}, 40.0))


# -- observability -----------------------------------------------------
def test_multi_pool_metrics_and_dashboard():
    pl = Placement(_hetero_pools())
    registry, bus, runner, sched = _engine(pl)
    monitor = JobMonitor(bus)
    _submit(registry, sched, _spec("c", resources={"vcpu": 4}))
    _submit(registry, sched, _spec("t", resources={"chips": 8}))
    sched.run_to_completion()
    # snapshots namespace dimensions per pool
    assert any("cpu/vcpu" in msg.get("utilization", {})
               for t, msg in bus.history if t == TOPIC_SCHEDULER)
    by_pool = monitor.utilization_by_pool()
    assert by_pool["cpu"]["vcpu"]["peak"] > 0.0
    assert by_pool["tpu"]["chips"]["peak"] > 0.0
    page = scheduler_page(sched, monitor)
    assert "cpu" in page and "tpu" in page and "placed" in page
    assert sched.stats["placed_by_pool"] == {"cpu": 1, "tpu": 1}


# -- legacy cluster reassignment ---------------------------------------
def test_cluster_reassignment_invalidates_placement_caches():
    """Swapping ``scheduler.cluster`` after jobs queued must re-derive
    their pool options instead of dispatching on stale rankings."""
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus)
    sched = Scheduler(registry, runner, bus, quota_k=10,
                      cluster=Cluster({"vcpu": 1.0}, {"vcpu": 0.5}))
    _submit(registry, sched, _spec("hog", duration=100.0,
                                   resources={"vcpu": 1}))
    waiting = _submit(registry, sched, _spec("w", duration=1.0,
                                             resources={"vcpu": 1}))
    assert registry.get(waiting.job_id).state == JobState.QUEUED
    sched.cluster = Cluster({"vcpu": 4.0}, {"vcpu": 0.5}, name="newpool")
    sched._maybe_launch()
    job = registry.get(waiting.job_id)
    assert job.state == JobState.RUNNING
    assert job.pool == "newpool"


def test_cluster_swap_fails_held_dependent_that_no_longer_fits():
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus)
    sched = Scheduler(registry, runner, bus, quota_k=10)   # unconstrained
    parent = _submit(registry, sched, _spec("parent", duration=5.0))
    child_spec = _spec("child", resources={"tpu": 8})
    child_spec.depends_on = [parent.job_id]
    child = _submit(registry, sched, child_spec)
    sched.cluster = Cluster({"vcpu": 4.0}, {"vcpu": 0.5})
    sched.run_to_completion()
    assert registry.get(parent.job_id).state == JobState.FINISHED
    child_job = registry.get(child.job_id)
    assert child_job.state == JobState.FAILED     # not a crash, not a hang
    assert "tpu" in child_job.error


# -- engine assembly ---------------------------------------------------
def test_acai_engine_builds_pools_from_catalog():
    eng = AcaiEngine(pricing=default_catalog(), virtual=True,
                     cluster_nodes={"cpu": 2, "tpu": 1}, quota_k=10)
    assert set(eng.pools) == {"cpu", "tpu"}
    h_cpu = eng.submit(JobSpec(name="c", project="p", user="u",
                               duration=1.0, resources={"vcpu": 2}))
    h_tpu = eng.submit(JobSpec(name="t", project="p", user="u",
                               duration=1.0, resources={"chips": 8}))
    assert h_cpu.wait() == JobState.FINISHED
    assert h_tpu.wait() == JobState.FINISHED
    assert h_cpu.job.pool == "cpu" and h_tpu.job.pool == "tpu"
    # infeasible everywhere -> terminal FAILED handle, not a hang
    h_bad = eng.submit(JobSpec(name="x", project="p", user="u",
                               duration=1.0, resources={"gpu": 4}))
    assert h_bad.wait() == JobState.FAILED


def test_catalog_without_nodes_is_refused():
    """A pricing catalog with no way to build pools must not silently
    produce an unconstrained engine billing through an arbitrary entry."""
    with pytest.raises(ValueError, match="cluster_nodes"):
        AcaiEngine(pricing=default_catalog(), virtual=True)


# -- CLI ---------------------------------------------------------------
def test_cli_pool_pin_requires_placement(tmp_path, capsys):
    """`submit --pool` on a deployment without a placement layer must
    refuse instead of silently dropping the pin."""
    from repro.core import cli
    assert cli.main(["--root", str(tmp_path), "init", "proj"]) == 0
    tok = capsys.readouterr().out.strip()
    rc = cli.main(["--root", str(tmp_path), "--token", tok,
                   "submit", "j", "--fn", "json:dumps", "--pool", "tpu"])
    assert rc == 2
    assert "pools deployment" in capsys.readouterr().err
    # malformed --resource exits cleanly too (no traceback)
    rc = cli.main(["--root", str(tmp_path), "--token", tok,
                   "submit", "j", "--fn", "json:dumps",
                   "--resource", "chips"])
    assert rc == 2
    assert "DIM=AMOUNT" in capsys.readouterr().err


# -- catalog-aware auto-provisioner ------------------------------------
def test_autoprovisioner_searches_across_pools():
    prof = _fit_pool_models()
    # alias the per-pool models under the names the provisioner derives
    prof.models["mnist@cpu"] = prof.models["work@cpu"]
    prof.models["mnist@tpu"] = prof.models["work@tpu"]
    prof.models["mnist"] = prof.models["work@cpu"]
    ap = AutoProvisioner(prof, {"cpu": CPU_PRICING, "tpu": TPU_PRICING})
    dec = ap.optimize_cost("mnist", {"work": 100.0}, max_runtime=1e6)
    assert dec.feasible
    assert dec.pool == "cpu"                   # tpu chips price it out
    assert {r["pool"] for r in dec.table} == {"cpu", "tpu"}
    dec_rt = ap.optimize_runtime("mnist", {"work": 100.0}, max_cost=1e6)
    assert dec_rt.pool == "tpu"                # 4x faster wins runtime
    # single-pricing callers keep the legacy shape
    dec_one = AutoProvisioner(prof, CPU_PRICING).optimize_cost(
        "mnist", {"work": 100.0}, max_runtime=1e6)
    assert dec_one.pool == "default" and dec_one.feasible
