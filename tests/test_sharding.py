"""Sharding rules: spec trees match param trees structurally for every
arch, every sharded dim divides evenly on the production meshes, and the
decode-state/batch specs are coherent (property-style sweep over all 10
archs x both meshes via AbstractMesh — no device initialization)."""
import functools

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_arch, list_archs
from repro.configs.shapes import SHAPES, applicable
from repro.models import model as M
from repro.models import transformer as T
from repro.sharding import make_abstract_mesh
from repro.sharding import rules as SR

MESHES = {
    "single": make_abstract_mesh((16, 16), ("data", "model")),
    "multi": make_abstract_mesh((2, 16, 16), ("pod", "data", "model")),
}


def _rules(mesh_name):
    return SR.AxisRules.for_mesh(MESHES[mesh_name])


def _param_shapes(arch):
    cfg = get_arch(arch)
    return cfg, jax.eval_shape(functools.partial(M.init_params, cfg),
                               jax.random.PRNGKey(0))


def _axis_size(mesh, entry):
    names = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh_name", ["single", "multi"])
def test_param_specs_match_and_divide(arch, mesh_name):
    cfg, shapes = _param_shapes(arch)
    rules = _rules(mesh_name)
    specs = SR.param_specs(cfg, rules, fsdp=True, param_shapes=shapes)
    mesh = MESHES[mesh_name]

    # structural match: tree.map succeeds leaf-for-leaf
    def check(sds, spec):
        assert isinstance(spec, P), spec
        assert len(spec) <= len(sds.shape), (sds.shape, spec)
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            size = _axis_size(mesh, entry)
            assert sds.shape[dim] % size == 0, \
                (arch, sds.shape, spec, dim)
        return 0

    jax.tree.map(check, shapes, specs,
                 is_leaf=lambda x: isinstance(x, P) or hasattr(x, "shape"))


@pytest.mark.parametrize("arch", list_archs())
def test_opt_state_specs_cover_params(arch):
    from repro.train.optimizer import opt_state_specs
    cfg, shapes = _param_shapes(arch)
    rules = _rules("single")
    pspecs = SR.param_specs(cfg, rules, fsdp=True, param_shapes=shapes)
    ospecs = opt_state_specs(pspecs, shapes, rules)
    assert set(ospecs) == {"mu", "nu", "step"}
    # moments shaped like params
    jax.tree.map(lambda a, b: None, pspecs, ospecs["mu"],
                 is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
@pytest.mark.parametrize("layout", ["fsdp", "resident"])
def test_decode_state_specs_match(arch, shape_name, layout):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, _ = applicable(cfg, shape)
    if not ok:
        pytest.skip("n/a cell")
    if cfg.family == "vlm":
        pytest.skip("vlm state init needs vision/params; covered by dryrun")
    rules = _rules("single")
    SR.set_rules(None)
    state_shapes = jax.eval_shape(functools.partial(
        T.init_decode_state, cfg, shape.global_batch, shape.seq_len))
    specs = SR.decode_state_specs(cfg, shape.global_batch, rules,
                                  layout=layout)
    mesh = MESHES["single"]

    def check(sds, spec):
        assert len(spec) <= len(sds.shape)
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            assert sds.shape[dim] % _axis_size(mesh, entry) == 0, \
                (arch, shape_name, layout, sds.shape, spec)

    if cfg.family == "vlm":
        pytest.skip("vlm state init needs vision/params; covered by dryrun")
    jax.tree.map(check, state_shapes, specs,
                 is_leaf=lambda x: isinstance(x, P) or hasattr(x, "shape"))


@pytest.mark.parametrize("gb,expected_sharded", [(256, True), (1, False)])
def test_batch_specs_small_batch_fallback(gb, expected_sharded):
    cfg = get_arch("qwen3-8b")
    rules = _rules("single")
    specs = SR.batch_specs(cfg, "train", gb, rules)
    sharded = specs["tokens"][0] is not None
    assert sharded == expected_sharded


def test_constrain_noop_without_rules():
    SR.set_rules(None)
    x = jnp.ones((4, 4))
    assert SR.constrain(x, ("batch", None)) is x
