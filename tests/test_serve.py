"""Serving correctness: the decode path (KV cache / SSM state threading)
must produce the same next-token logits as the parallel forward path —
teacher-forcing parity, the strongest cache-machinery test."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.models import model as M
from repro.models import transformer as T

PARITY_ARCHS = ["olmo-1b", "qwen3-8b", "rwkv6-7b", "zamba2-7b",
                "musicgen-large", "llama-3.2-vision-11b",
                "olmoe-1b-7b"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_parallel_forward(arch):
    import dataclasses
    cfg = get_arch(arch).reduced()
    if cfg.moe is not None:
        # capacity-based MoE drops differ between a whole-sequence routing
        # queue and per-step decode; parity is exact only when nothing
        # drops -> give the test an overflow-proof capacity factor
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    key = jax.random.PRNGKey(1)
    if cfg.n_codebooks:
        tokens = jax.random.randint(key, (b, s, cfg.n_codebooks), 0,
                                    cfg.vocab_size)
    else:
        tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    vision = None
    if cfg.family == "vlm":
        vision = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.n_vision_tokens,
                                    cfg.vision_dim)).astype(jnp.bfloat16)

    # parallel forward (fp32 compute for a tight reference)
    ctx = M.make_ctx(cfg, s, "train", vision=vision, remat=None,
                     compute_dtype=jnp.float32)
    ref_logits, _, _ = M.forward(params, tokens, cfg, ctx)

    # decode path, token by token
    states = T.init_decode_state(cfg, b, s, dtype=jnp.float32,
                                 vision=vision, params=params)
    cache_len = jnp.zeros((b,), jnp.int32)
    outs = []
    for t in range(s):
        tok = tokens[:, t:t + 1]
        dctx = M.make_ctx(cfg, s, "decode", vision=vision,
                          cache_len=cache_len,
                          compute_dtype=jnp.float32)
        logits, states = M.decode_step(params, tok, states, cache_len,
                                       cfg, dctx)
        outs.append(logits)
        cache_len = cache_len + 1
    dec_logits = jnp.concatenate(outs, axis=1)

    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(ref_logits, np.float32), rtol=2e-3, atol=2e-3)


def test_greedy_generate_shapes():
    from repro.serve.decode import greedy_generate
    cfg = get_arch("olmo-1b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                cfg.vocab_size)
    out = greedy_generate(cfg, params, prompt, max_new=4)
    assert out.shape == (2, 4)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())
