"""Data-lake behaviour: versioning, filesets, sessions, metadata, provenance."""
import pytest

from repro.core.datalake.fileset import FileSetManager
from repro.core.datalake.metadata import MetadataStore
from repro.core.datalake.provenance import ProvenanceGraph
from repro.core.datalake.storage import DataLakeError, Storage


@pytest.fixture
def lake(tmp_path):
    storage = Storage(tmp_path)
    prov = ProvenanceGraph(tmp_path)
    fs = FileSetManager(storage, prov)
    meta = MetadataStore(tmp_path)
    return storage, fs, prov, meta


def test_versioning_sequential_no_gaps(lake):
    storage, *_ = lake
    for i in range(3):
        fv = storage.upload("/data/train.json", f"v{i}".encode())
        assert fv.version == i + 1
    assert storage.versions("/data/train.json") == [1, 2, 3]
    assert storage.download("/data/train.json") == b"v2"
    assert storage.download("/data/train.json@1") == b"v0"


def test_versions_immutable_content_addressed(lake):
    storage, *_ = lake
    storage.upload("/a", b"hello")
    storage.upload("/a", b"world")
    assert storage.download("/a@1") == b"hello"   # old version intact


def test_upload_session_transactional(lake):
    storage, *_ = lake
    sid = storage.begin_session(["/x", "/y"])
    storage.session_put(sid, "/x", b"1")
    with pytest.raises(DataLakeError):
        storage.commit_session(sid)           # /y missing -> no commit
    # failed commit must not burn version numbers
    assert storage.versions("/x") == []
    storage.session_put(sid, "/y", b"2")
    fvs = storage.commit_session(sid)
    assert sorted(f.version for f in fvs) == [1, 1]
    assert storage.session_state(sid) == "committed"


def test_session_abort(lake):
    storage, *_ = lake
    sid = storage.begin_session(["/z"])
    storage.session_put(sid, "/z", b"zz")
    storage.abort_session(sid)
    assert storage.session_state(sid) == "aborted"
    assert not storage.exists("/z")
    with pytest.raises(DataLakeError):
        storage.session_put(sid, "/z", b"again")


def test_session_survives_reload(tmp_path):
    s1 = Storage(tmp_path)
    sid = s1.begin_session(["/p"])
    s1.session_put(sid, "/p", b"data")
    # crash + restart: session state persisted, client free to continue
    s2 = Storage(tmp_path)
    assert s2.session_state(sid) == "pending"
    fvs = s2.commit_session(sid)
    assert fvs[0].version == 1


def test_fileset_merge_update_subset(lake):
    storage, fs, prov, _ = lake
    storage.upload("/data/train.json", b"t1")
    storage.upload("/data/dev.json", b"d1")
    storage.upload("/validation/val.json", b"v1")
    fs.create("HotpotQA", ["/data/train.json", "/validation/val.json"])
    fs.create("ColdpotQA", ["/data/dev.json"])
    # merging (paper example 1)
    merged = fs.merge("MergedQA", ["HotpotQA", "ColdpotQA"])
    assert set(merged.files) == {"/data/train.json", "/validation/val.json",
                                 "/data/dev.json"}
    # updating (paper example 2): new version of the file replaces old ref
    storage.upload("/data/train.json", b"t2")
    updated = fs.update("HotpotQA", ["/data/train.json"])
    assert updated.version == 2
    assert updated.files["/data/train.json"] == 2
    # old set version still pins the old file version
    assert fs.resolve("HotpotQA:1").files["/data/train.json"] == 1
    # subsetting (paper example 3)
    sub = fs.subset("HotpotQAValidationSet", "HotpotQA:1", "/validation/")
    assert set(sub.files) == {"/validation/val.json"}
    # dependencies recorded in provenance
    assert ("HotpotQA:1", {"action": "fileset_creation", "creator": ""}) in \
        prov.backward("HotpotQAValidationSet:1")


def test_fileset_file_at_set_version(lake):
    storage, fs, _, _ = lake
    storage.upload("/data/train.json", b"t1")
    fs.create("S", ["/data/train.json"])
    storage.upload("/data/train.json", b"t2")
    # '/data/train.json@S:1' resolves via the set
    got, _ = fs._expand_spec("/data/train.json@S:1")
    assert got == {"/data/train.json": 1}


def test_fileset_single_version_per_file(lake):
    storage, fs, _, _ = lake
    storage.upload("/a", b"1")
    storage.upload("/a", b"2")
    fsv = fs.create("S", ["/a@1", "/a@2"])
    # later spec wins; a set never holds two versions of one file
    assert fsv.files == {"/a": 2}


def test_materialize_unversioned(lake, tmp_path):
    storage, fs, _, _ = lake
    storage.upload("/data/train.json", b"payload")
    fs.create("S", ["/data/train.json"])
    out = fs.materialize("S", tmp_path / "job")
    assert len(out) == 1
    assert (tmp_path / "job/data/train.json").read_bytes() == b"payload"


def test_metadata_queries(lake):
    *_, meta = lake
    meta.register("job-1", kind="job", creator="john", model="BERT",
                  precision=0.7)
    meta.register("job-2", kind="job", creator="john", model="BERT",
                  precision=0.4)
    meta.register("job-3", kind="job", creator="mary", model="GPT",
                  precision=0.9)
    # the paper's exemplar query: john's BERT jobs with precision > 0.5
    hits = meta.find(creator="john", model="BERT", precision=(">", 0.5))
    assert hits == ["job-1"]
    assert meta.find_max("precision", kind="job") == "job-3"
    assert meta.find_min("precision", creator="john") == "job-2"
    rng = meta.find(precision=("range", 0.35, 0.75))
    assert rng == ["job-1", "job-2"]


def test_metadata_tags_and_reload(tmp_path):
    meta = MetadataStore(tmp_path)
    meta.register("f-1", kind="file")
    meta.tag("f-1", "best")
    meta2 = MetadataStore(tmp_path)
    assert meta2.find(tags=["best"]) == ["f-1"]


def test_provenance_dag_traversal(lake):
    _, _, prov, _ = lake
    prov.add_fileset("raw:1")
    prov.add_job_edge(src="raw:1", dst="features:1", job_id="job-etl")
    prov.add_job_edge(src="features:1", dst="model:1", job_id="job-train")
    assert prov.forward("raw:1")[0][0] == "features:1"
    assert prov.backward("model:1")[0][0] == "features:1"
    assert prov.ancestors("model:1") == ["features:1", "raw:1"]
    assert prov.lineage_jobs("model:1") == ["job-etl", "job-train"]
    assert prov.replay_order("model:1")[0] == "raw:1"
    assert prov.is_dag()
