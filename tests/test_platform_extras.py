"""Dashboard rendering, workflow replay (§7.1.3), inter-job fileset cache
(§7.1.2), and the CLI round-trip."""

import pytest

from repro.core.acai import AcaiPlatform
from repro.core.datalake.cache import FilesetCache
from repro.core.engine.dashboard import job_history, provenance_page
from repro.core.engine.registry import JobSpec
from repro.core.engine.replay import WorkflowReplayer


@pytest.fixture
def platform(tmp_path):
    plat = AcaiPlatform(tmp_path)
    admin = plat.create_project(plat.admin_token, "p")
    return plat, admin


def _etl_and_train(plat, admin):
    proj = plat.project(admin)
    proj.upload("/raw/data.txt", b"1 2 3 4", creator="a")
    proj.create_file_set("Raw", ["/raw/data.txt"], creator="a")

    def etl(workdir, job):
        nums = (workdir / "raw/data.txt").read_text().split()
        (workdir / "out/features.txt").write_text(
            " ".join(str(2 * int(n)) for n in nums))
        print("[[acai:rows=4]]")

    def train(workdir, job):
        feats = [int(x) for x in
                 (workdir / "Features/features.txt").read_text().split()]
        (workdir / "out/model.txt").write_text(str(sum(feats)))
        print(f"[[acai:training_loss={1.0 / max(sum(feats), 1)}]]")

    j1 = plat.submit_job(admin, JobSpec(
        name="etl", project="", user="", fn=etl, input_fileset="Raw",
        output_fileset="Features", resources={"vcpu": 1, "mem_mb": 512}))
    j2 = plat.submit_job(admin, JobSpec(
        name="train", project="", user="", fn=train,
        input_fileset="Features", output_fileset="Model",
        resources={"vcpu": 1, "mem_mb": 512}))
    return proj, j1, j2


def test_dashboard_pages(platform):
    plat, admin = platform
    proj, j1, j2 = _etl_and_train(plat, admin)
    eng = plat.engine(admin)
    page = job_history(eng.registry, proj.metadata)
    assert "etl" in page and "train" in page and "FINISHED" in page
    assert "rows=4" in page                      # log-parser tag surfaced
    # filtering + sorting + pagination
    page = job_history(eng.registry, proj.metadata, status="FINISHED",
                       sort_by="runtime", descending=True, page_size=1)
    assert "page 1 of 2 (2 jobs)" in page
    whole = provenance_page(proj.provenance)
    assert "Raw:1" in whole and "Model:1" in whole
    trace = provenance_page(proj.provenance, "Model:1")
    assert "Features:1" in trace and "Raw:1" in trace
    fwd = provenance_page(proj.provenance, "Raw:1", direction="forward")
    assert "Features:1" in fwd


def test_workflow_replay(platform):
    plat, admin = platform
    proj, j1, j2 = _etl_and_train(plat, admin)
    eng = plat.engine(admin)
    replayer = WorkflowReplayer(proj, eng)
    plan = replayer.plan("Model:1")
    assert [s["job_id"] for s in plan] == [j1.job_id, j2.job_id]
    new_ids = replayer.replay("Model:1")
    assert len(new_ids) == 2
    # replay produced NEW versions of the same filesets, same content
    assert proj.filesets.resolve("Model").version == 2
    assert proj.storage.download("/Model/model.txt") == b"20"
    # dependency chain intact for the replayed generation
    back = proj.provenance.backward("Model:2")
    assert any(src == "Features:2" for src, _ in back)


def test_replay_with_override_input(platform):
    plat, admin = platform
    proj, j1, j2 = _etl_and_train(plat, admin)
    proj.upload("/raw/data.txt", b"10 20 30 40", creator="a")
    proj.create_file_set("Raw2", ["/raw/data.txt"], creator="a")
    eng = plat.engine(admin)
    new_ids = WorkflowReplayer(proj, eng).replay("Model:1",
                                                 override_input="Raw2:1")
    assert proj.storage.download("/Model/model.txt") == b"200"


def test_fileset_cache(platform, tmp_path):
    plat, admin = platform
    proj = plat.project(admin)
    proj.upload("/d/a.txt", b"x" * 100, creator="a")
    proj.create_file_set("S", ["/d/a.txt"], creator="a")
    cache = FilesetCache(tmp_path / "cache", max_bytes=10_000)
    hit1 = cache.materialize(proj.filesets, "S", tmp_path / "j1")
    hit2 = cache.materialize(proj.filesets, "S", tmp_path / "j2")
    assert (not hit1) and hit2
    assert (tmp_path / "j2/d/a.txt").read_bytes() == b"x" * 100
    # a NEW fileset version is a different cache key (never stale)
    proj.upload("/d/a.txt", b"y" * 100, creator="a")
    proj.create_file_set("S", ["/d/a.txt"], creator="a")
    hit3 = cache.materialize(proj.filesets, "S", tmp_path / "j3")
    assert not hit3
    assert (tmp_path / "j3/d/a.txt").read_bytes() == b"y" * 100
    assert cache.stats["hits"] == 1 and cache.stats["misses"] == 2


def test_cache_eviction(tmp_path, platform):
    plat, admin = platform
    proj = plat.project(admin)
    cache = FilesetCache(tmp_path / "c", max_bytes=250)
    for i in range(3):
        proj.upload(f"/f{i}.bin", bytes(100), creator="a")
        proj.create_file_set(f"FS{i}", [f"/f{i}.bin"], creator="a")
        cache.materialize(proj.filesets, f"FS{i}", tmp_path / f"o{i}")
    assert cache.stats["bytes"] <= 250


def test_cli_roundtrip(tmp_path, capsys):
    from repro.core.cli import main
    root = str(tmp_path / "cli")
    assert main(["--root", root, "init", "demo"]) == 0
    token = capsys.readouterr().out.strip()
    data = tmp_path / "payload.txt"
    data.write_text("hello")
    assert main(["--root", root, "--token", token, "upload",
                 "/data/x.txt", str(data)]) == 0
    assert capsys.readouterr().out.strip() == "/data/x.txt@1"
    assert main(["--root", root, "--token", token, "create-file-set",
                 "D", "/data/x.txt"]) == 0
    assert capsys.readouterr().out.strip() == "D:1"
    assert main(["--root", root, "--token", token, "ls"]) == 0
    out = capsys.readouterr().out
    assert "/data/x.txt" in out and "@D" in out
    assert main(["--root", root, "--token", token, "find",
                 "kind=fileset"]) == 0
    assert "D:1" in capsys.readouterr().out
