"""ThreadPoolRunner: the LocalRunner agent protocol on a bounded worker
pool — same lifecycle events, provenance edges, and log/metadata capture
as the synchronous runner, plus concurrency, quota and capacity behavior
under threads."""
import threading
import time

import pytest

from repro.core.acai import AcaiPlatform
from repro.core.engine.cluster import Cluster
from repro.core.engine.lifecycle import JobState
from repro.core.engine.registry import JobSpec


@pytest.fixture
def platform(tmp_path):
    plat = AcaiPlatform(tmp_path, runner="thread", max_workers=4)
    admin = plat.create_project(plat.admin_token, "proj")
    return plat, admin


def test_agent_protocol_end_to_end_threaded(platform):
    """The LocalRunner e2e flow from test_engine.py, unchanged in behavior:
    download -> run -> upload -> publish, provenance edge, log-parsed
    metadata, cost — just drained through run_all() instead of returning
    synchronously from submit."""
    plat, admin = platform
    proj = plat.project(admin)
    proj.upload("/data/in.txt", b"42", creator="admin")
    proj.create_file_set("inputs", ["/data/in.txt"], creator="admin")

    def fn(workdir, job):
        val = int((workdir / "data/in.txt").read_text())
        (workdir / "out/result.txt").write_text(str(val * 2))
        print(f"[[acai:answer={val * 2}]]")
        return {"answer": val * 2}

    job = plat.submit_job(admin, JobSpec(
        name="double", project="", user="", fn=fn,
        input_fileset="inputs", output_fileset="outputs",
        resources={"vcpu": 1, "mem_mb": 1024}))
    eng = plat.engine(admin)
    eng.run_all()
    j = eng.registry.get(job.job_id)
    assert j.state == JobState.FINISHED
    assert j.outputs["answer"] == 84
    fsv = proj.filesets.resolve("outputs")
    assert "/outputs/result.txt" in fsv.files
    assert proj.storage.download("/outputs/result.txt") == b"84"
    back = proj.provenance.backward("outputs:1")
    assert ("inputs:1", {"action": "job", "job_id": job.job_id,
                         "creator": "proj-admin"}) in back
    md = proj.metadata.get(job.job_id)
    assert md["answer"] == 84
    assert md["cost"] > 0
    stages = [e.get("stage") for e in eng.monitor.watch(job.job_id)
              if "stage" in e]
    assert stages == ["downloading", "running", "uploading"]


def test_failed_job_threaded(platform):
    plat, admin = platform

    def boom(workdir, job):
        raise RuntimeError("user code crashed")

    job = plat.submit_job(admin, JobSpec(name="bad", project="", user="",
                                         fn=boom))
    eng = plat.engine(admin)
    eng.run_all()
    j = eng.registry.get(job.job_id)
    assert j.state == JobState.FAILED
    assert "user code crashed" in j.error


def test_bounded_workers_and_quota(tmp_path):
    """max_workers=2 bounds real concurrency; quota_k bounds per-queue
    admission; all jobs finish after the drain."""
    plat = AcaiPlatform(tmp_path, runner="thread", max_workers=2,
                        quota_k=2)
    admin = plat.create_project(plat.admin_token, "proj")
    running = []
    peak = []
    lock = threading.Lock()

    def fn(workdir, job):
        with lock:
            running.append(job.job_id)
            peak.append(len(running))
        time.sleep(0.05)
        with lock:
            running.remove(job.job_id)

    jobs = [plat.submit_job(admin, JobSpec(name=f"j{i}", project="",
                                           user="", fn=fn))
            for i in range(8)]
    eng = plat.engine(admin)
    eng.run_all()
    assert all(eng.registry.get(j.job_id).state == JobState.FINISHED
               for j in jobs)
    assert max(peak) <= 2


def test_capacity_respected_across_threads(tmp_path):
    """With a 2-vcpu cluster and 1-vcpu jobs, at most two run at once even
    though the pool has more workers; capacity is never oversubscribed."""
    plat = AcaiPlatform(tmp_path, runner="thread", max_workers=4,
                        quota_k=100)
    admin = plat.create_project(plat.admin_token, "proj")
    eng = plat.engine(admin)
    cl = Cluster({"vcpu": 2.0}, {"vcpu": 0.5})
    eng.scheduler.cluster = cl
    eng.cluster = cl
    running = []
    peak = []
    lock = threading.Lock()

    def fn(workdir, job):
        with lock:
            running.append(job.job_id)
            peak.append(len(running))
        time.sleep(0.03)
        with lock:
            running.remove(job.job_id)

    jobs = [plat.submit_job(admin, JobSpec(
        name=f"j{i}", project="", user="", fn=fn,
        resources={"vcpu": 1})) for i in range(6)]
    eng.run_all()
    assert all(eng.registry.get(j.job_id).state == JobState.FINISHED
               for j in jobs)
    assert max(peak) <= 2
    assert all(v == 0.0 for v in cl.used.values())


def test_concurrent_output_filesets_and_provenance(tmp_path):
    """Many workers uploading output filesets + provenance edges + parsed
    metadata concurrently: every artifact lands, nothing corrupts."""
    plat = AcaiPlatform(tmp_path, runner="thread", max_workers=4)
    admin = plat.create_project(plat.admin_token, "proj")
    proj = plat.project(admin)

    def fn(workdir, job):
        i = job.spec.args["i"]
        (workdir / "out/part.txt").write_text(str(i))
        print(f"[[acai:part={i}]]")

    jobs = [plat.submit_job(admin, JobSpec(
        name=f"w{i}", project="", user="", fn=fn, args={"i": i},
        output_fileset=f"out-{i}")) for i in range(12)]
    eng = plat.engine(admin)
    eng.run_all()
    for i, j in enumerate(jobs):
        assert eng.registry.get(j.job_id).state == JobState.FINISHED, \
            eng.registry.get(j.job_id).error
        assert proj.storage.download(f"/out-{i}/part.txt") == \
            str(i).encode()
        assert proj.metadata.get(j.job_id)["part"] == i
        assert proj.filesets.resolve(f"out-{i}").version == 1
    assert proj.provenance.is_dag()


def test_kill_while_running_on_worker(platform):
    """Killing a job mid-run on a worker thread must not clobber the
    KILLED state with FINISHED, and the terminal status reaches the
    monitor and metadata."""
    plat, admin = platform
    proj = plat.project(admin)
    started = threading.Event()

    def slow(workdir, job):
        started.set()
        time.sleep(0.3)

    job = plat.submit_job(admin, JobSpec(name="victim", project="",
                                         user="", fn=slow))
    eng = plat.engine(admin)
    assert started.wait(5.0)
    eng.scheduler.kill(job.job_id)
    eng.run_all()
    assert eng.registry.get(job.job_id).state == JobState.KILLED
    assert eng.monitor.status[job.job_id] == "KILLED"
    assert proj.metadata.get(job.job_id)["state"] == "KILLED"


def test_training_workflow_threaded(tmp_path):
    """The test_system.py workflow shape (upload -> fileset -> jobs ->
    metadata query) through the thread pool."""
    plat = AcaiPlatform(tmp_path, runner="thread", max_workers=4)
    admin = plat.create_project(plat.admin_token, "e2e")
    proj = plat.project(admin)
    proj.upload("/data/dataset.json", b'{"seed": 7}', creator="e2e")
    proj.create_file_set("TrainData", ["/data/dataset.json"], creator="e2e")

    def train_job(workdir, job):
        lr = job.spec.args["lr"]
        loss = 1.0 / lr          # deterministic stand-in for training
        print(f"[[acai:final_loss={loss}]]")

    jobs = [plat.submit_job(admin, JobSpec(
        name=f"train-lr{lr}", project="", user="", fn=train_job,
        input_fileset="TrainData", args={"lr": lr},
        resources={"vcpu": 2, "mem_mb": 2048})) for lr in (3e-3, 1e-4)]
    eng = plat.engine(admin)
    eng.run_all()
    for j in jobs:
        assert eng.registry.get(j.job_id).state == JobState.FINISHED, \
            eng.registry.get(j.job_id).error
    best = proj.metadata.find_min("final_loss", kind="job")
    assert eng.registry.get(best).spec.args["lr"] == pytest.approx(3e-3)
