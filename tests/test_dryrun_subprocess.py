"""Dry-run machinery smoke test in a subprocess (needs its own process:
XLA locks the host-device count at first init; the suite must keep 1)."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 4), ("data", "model"))
out = {}
r = run_cell("olmo-1b", "decode_32k", mesh=mesh, out_dir=None,
             verbose=False)
out["decode"] = {"status": r["status"],
                 "dominant": r["roofline"]["dominant"],
                 "coll": r["roofline"]["collective_s"]}
r = run_cell("qwen3-8b", "long_500k", mesh=mesh, out_dir=None,
             verbose=False)
out["na"] = r["status"]
print("RESULT::" + json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_cell_in_subprocess():
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=560,
                          env={**__import__("os").environ,
                               "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT::")][0]
    out = json.loads(line[len("RESULT::"):])
    assert out["decode"]["status"] == "ok"
    assert out["na"] == "n/a"          # full-attention arch skips long_500k
