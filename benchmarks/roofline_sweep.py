"""Collate dry-run JSON artifacts into the EXPERIMENTS.md §Roofline table
(+ per-cell bottleneck advice)."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).parent / "results" / "dryrun"


def advice(r: dict) -> str:
    """One sentence on what would move the dominant term down (per cell)."""
    f = r["roofline"]
    dom = f["dominant"]
    kind = ("train" if "train" in r["shape"] else
            "decode" if "decode" in r["shape"] or "long" in r["shape"]
            else "prefill")
    coll = f.get("collective_breakdown", {})
    ag = coll.get("all-gather", 0)
    ar = coll.get("all-reduce", 0)
    if dom == "collective":
        if kind == "decode":
            return ("switch to the resident serving layout (bf16 TP-only "
                    "weights, no per-token FSDP gathers) — §Perf C")
        if ar >= ag:
            return ("reduce TP width toward data-parallel (TP psum bytes "
                    "scale with local tokens) — §Perf A3-A5/B3")
        return ("raise TP width or stream bf16 params (FSDP gather-bound) "
                "— §Perf A6 shows the opposite wall")
    if dom == "memory":
        if kind == "decode":
            return ("cache-insert aliasing + flash-decode kernel remove the "
                    "rewrite and score traffic (§Perf C3 note)")
        if f.get("useful_flops_ratio", 1) < 0.6:
            return ("dots-saveable remat + Pallas flash attention cut "
                    "recompute and score HBM traffic — §Perf A1/A8")
        return ("Pallas fused kernels (attention/WKV6/SSD) keep block "
                "intermediates in VMEM — kernels/ lower on real TPU")
    return ("compute-bound: raise useful ratio (lighter remat, causal "
            "block-skip in the Pallas kernel), or add chips")


def load(results_dir=RESULTS) -> list[dict]:
    rows = []
    for p in sorted(Path(results_dir).glob("*.json")):
        rows.append(json.loads(p.read_text()))
    return rows


def markdown_table(rows: list[dict], *, multi_pod: bool = False) -> str:
    hdr = ("| arch | shape | chips | compute_s | memory_s | collective_s | "
           "dominant | MODEL_FLOPS | useful | roofline_frac |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.get("multi_pod") != multi_pod:
            continue
        if r.get("status") == "n/a":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                       f"N/A | - | - | - |\n")
            continue
        f = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['n_chips']} "
            f"| {f['compute_s']:.3f} | {f['memory_s']:.3f} "
            f"| {f['collective_s']:.3f} | {f['dominant']} "
            f"| {f['model_flops']:.3e} | {f['useful_flops_ratio']:.2f} "
            f"| {f['roofline_fraction']:.3f} |\n")
    return "".join(out)


def summary(rows: list[dict]) -> dict:
    ok = [r for r in rows if r.get("status") == "ok"]
    single = [r for r in ok if not r["multi_pod"]]
    multi = [r for r in ok if r["multi_pod"]]
    na = [r for r in rows if r.get("status") == "n/a"]
    return {
        "cells_ok_single": len(single), "cells_ok_multi": len(multi),
        "cells_na": len(na),
        "worst_roofline": min(
            ((r["arch"], r["shape"], r["roofline"]["roofline_fraction"])
             for r in single), key=lambda t: t[2], default=None),
        "best_roofline": max(
            ((r["arch"], r["shape"], r["roofline"]["roofline_fraction"])
             for r in single), key=lambda t: t[2], default=None),
    }


def advice_table(rows: list[dict], *, multi_pod: bool = False) -> str:
    out = ["| arch | shape | dominant | what moves it down |\n"
           "|---|---|---|---|\n"]
    for r in rows:
        if r.get("multi_pod") != multi_pod or r.get("status") != "ok":
            continue
        out.append(f"| {r['arch']} | {r['shape']} | "
                   f"{r['roofline']['dominant']} | {advice(r)} |\n")
    return "".join(out)


def annotate(results_dir=RESULTS) -> None:
    """Write the advice sentence back into each JSON artifact."""
    for p in sorted(Path(results_dir).glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "ok":
            r["bottleneck_advice"] = advice(r)
            p.write_text(json.dumps(r, indent=1))


if __name__ == "__main__":
    rows = load()
    print(markdown_table(rows))
    print(advice_table(rows))
    print(json.dumps(summary(rows), indent=1))
    annotate()
