"""Paper Table 1 — runtime-prediction error of the log-linear profiler.

Faithful methodology reproduction with REAL measured runtimes: a real JAX
MLP training job (the paper's MNIST task, synthetic data) is profiled over
a grid of (epochs x hidden x batch); the log-linear model is fit on the
grid and evaluated on an EXTRAPOLATED grid (the paper trains on epochs
{1,2,3} and evaluates on {5,10,20}), against the paper's averaging
baseline. Paper reports: L1 224.82 s vs 2105.71 s baseline, 98 % variance
explained. We report the same three numbers on our task.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.provision.profiler import CommandTemplate, LogLinearModel


def _mlp_job(epochs: int, hidden: int, batch: int, *, steps_per_epoch=30,
             dim=784, classes=10, seed=0) -> float:
    """One real training run; returns wall seconds."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    w1 = jax.random.normal(k1, (dim, hidden)) * 0.05
    w2 = jax.random.normal(k2, (hidden, classes)) * 0.05
    x = jax.random.normal(k3, (batch * steps_per_epoch, dim))
    y = jax.random.randint(k4, (batch * steps_per_epoch,), 0, classes)

    @jax.jit
    def step(w1, w2, xb, yb):
        def loss(w1, w2):
            logits = jnp.tanh(xb @ w1) @ w2
            return -jnp.mean(jax.nn.log_softmax(logits)[
                jnp.arange(xb.shape[0]), yb])
        g1, g2 = jax.grad(loss, argnums=(0, 1))(w1, w2)
        return w1 - 0.1 * g1, w2 - 0.1 * g2

    # warmup/compile outside the measured window
    w1, w2 = step(w1, w2, x[:batch], y[:batch])
    jax.block_until_ready(w1)
    t0 = time.perf_counter()
    for _ in range(epochs):
        for s in range(steps_per_epoch):
            lo = s * batch
            w1, w2 = step(w1, w2, x[lo:lo + batch], y[lo:lo + batch])
    jax.block_until_ready(w1)
    return time.perf_counter() - t0


TEMPLATE = CommandTemplate(
    name="mlp-train",
    hints={"epochs": [1, 2, 3]},
    resource_hints={"hidden": [64, 128, 256], "batch": [32, 64, 128]})

EVAL_GRID = [{"epochs": e, "hidden": h, "batch": b}
             for e in (5, 8) for h in (96, 192, 384) for b in (48, 96, 192)]


def run() -> dict:
    grid = TEMPLATE.grid()
    runtimes = [_mlp_job(int(c["epochs"]), int(c["hidden"]),
                         int(c["batch"])) for c in grid]
    model = LogLinearModel(TEMPLATE.feature_names).fit(grid, runtimes)
    true = np.array([_mlp_job(int(c["epochs"]), int(c["hidden"]),
                              int(c["batch"])) for c in EVAL_GRID])
    pred = model.predict_many(EVAL_GRID)
    ours = LogLinearModel.errors(pred, true)
    base = LogLinearModel.errors(np.full_like(true, true.mean()), true)
    return {
        "table": "1 (runtime prediction)",
        "train_trials": len(grid), "eval_trials": len(EVAL_GRID),
        "mean_eval_runtime_s": float(true.mean()),
        "loglinear_l1_s": ours["l1"], "loglinear_l2_s2": ours["l2"],
        "averaging_l1_s": base["l1"], "averaging_l2_s2": base["l2"],
        "variance_explained": ours["variance_explained"],
        "paper_variance_explained": 0.98,
    }


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
