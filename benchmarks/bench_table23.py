"""Paper Tables 2 & 3 — auto-provisioned resource configs vs baseline.

TPU adaptation of the paper's MNIST experiment: the job is "train qwen3-8b
for N steps at train_4k"; resources are (chips, per-chip HBM GB) under the
linear-unit-price TPU pricing. The profiling fleet runs through the REAL
execution engine (virtual clock, 95 % quorum) against the roofline oracle;
the log-linear model is fit on the explored grid exactly as §4.2.2; the
auto-provisioner then
  Table 2: fixes max cost = baseline cost, optimizes runtime (paper: 1.7x)
  Table 3: fixes max runtime = baseline runtime, optimizes cost (paper:
           35–39 % saving)
Baseline config = 32 chips / 16 GB (the "n1-standard-2 of pods").
"""
from __future__ import annotations

import numpy as np

from benchmarks.oracle import job_time
from repro.configs.base import get_arch
from repro.configs.shapes import get_shape
from repro.core.acai import AcaiPlatform
from repro.core.engine.registry import JobSpec
from repro.core.provision.autoprovision import AutoProvisioner
from repro.core.provision.pricing import TPU_PRICING
from repro.core.provision.profiler import CommandTemplate

ARCH = "qwen3-8b"
SHAPE = "train_4k"

TEMPLATE = CommandTemplate(
    name="qwen3-8b-train",
    hints={"steps": [50, 100, 200]},
    resource_hints={"chips": [8, 32, 128], "hbm_gb": [4, 8, 16]})

# the "n1-standard-2 of pods": a balanced default that over-reserves HBM —
# mirroring the paper's baseline (2 vCPU + 7.5 GB) whose memory the MNIST
# job never used. The provisioner should trade HBM down for chips up.
BASELINE = {"chips": 32, "hbm_gb": 16}
EVAL_STEPS = [200, 500]


def _true_runtime(cfg_dict, rng=None, noise=0.0):
    cfg = get_arch(ARCH)
    shape = get_shape(SHAPE)
    return job_time(cfg, shape, cfg_dict["steps"], cfg_dict["chips"],
                    cfg_dict["hbm_gb"], rng, noise)


def run(seed: int = 0, noise: float = 0.05) -> dict:
    rng = np.random.default_rng(seed)
    plat = AcaiPlatform("/tmp/acai-bench23", virtual=True, quota_k=10_000,
                        pricing=TPU_PRICING,
                        oracle=lambda job: _true_runtime(job.spec.args,
                                                         rng, noise))
    admin = plat.create_project(plat.admin_token, f"bench23-{seed}")
    profiler = plat.make_profiler(admin)

    class _Eng:
        registry = plat.engine(admin).registry
        scheduler = plat.engine(admin).scheduler

        @staticmethod
        def submit(spec):
            return plat.submit_job(admin, spec)

    profiler.engine = _Eng()
    profiler.profile(TEMPLATE, lambda cfg: JobSpec(
        name="prof", project="", user="", args=cfg,
        resources={k: cfg[k] for k in ("chips", "hbm_gb")}))
    ap = AutoProvisioner(profiler, TPU_PRICING)

    rows = []
    measure = lambda cfg: _true_runtime(cfg, rng, noise)
    for steps in EVAL_STEPS:
        values = {"steps": steps}
        t_base = _true_runtime({**values, **BASELINE})
        c_base = TPU_PRICING.job_cost(BASELINE, t_base)
        # Table 2: fix cost, optimize runtime — with active refinement
        # (the plain paper search extrapolates past the collective wall
        # and overshoots the budget; refinement measures + refits)
        d2, hist2 = ap.refined_search(TEMPLATE.name, values,
                                      measure_fn=measure,
                                      objective="runtime",
                                      max_cost=c_base)
        t2_true = _true_runtime({**values, **d2.resources}) \
            if d2.feasible else float("nan")
        c2_true = TPU_PRICING.job_cost(d2.resources, t2_true) \
            if d2.feasible else float("nan")
        # Table 3: fix runtime, optimize cost
        d3, hist3 = ap.refined_search(TEMPLATE.name, values,
                                      measure_fn=measure,
                                      objective="cost",
                                      max_runtime=t_base)
        t3_true = _true_runtime({**values, **d3.resources}) \
            if d3.feasible else float("nan")
        c3_true = TPU_PRICING.job_cost(d3.resources, t3_true) \
            if d3.feasible else float("nan")
        rows.append({
            "steps": steps,
            "baseline": dict(BASELINE), "baseline_runtime_s": t_base,
            "baseline_cost": c_base,
            "t2_resources": d2.resources, "t2_runtime_s": t2_true,
            "t2_cost": c2_true,
            "t2_speedup": t_base / t2_true if d2.feasible else None,
            "t3_resources": d3.resources, "t3_runtime_s": t3_true,
            "t3_cost": c3_true,
            "t3_cost_saving": 1 - c3_true / c_base if d3.feasible else None,
            "t2_within_budget": bool(d2.feasible and c2_true
                                     <= c_base * 1.02),
            "t2_refinement_rounds": len(hist2),
            "t3_refinement_rounds": len(hist3),
        })
    return {"table": "2+3 (auto-provisioning)", "arch": ARCH,
            "paper_speedup": 1.74, "paper_cost_saving": 0.388,
            "rows": rows}


def run_multi(n_seeds: int = 3, noise: float = 0.05) -> dict:
    """Noise makes single-seed refinement decisions jumpy (the paper also
    averages 3 runs per cell) — aggregate across seeds."""
    import numpy as _np
    runs = [run(seed=s, noise=noise) for s in range(n_seeds)]
    rows = []
    for i, steps in enumerate(EVAL_STEPS):
        sp = [r["rows"][i]["t2_speedup"] for r in runs
              if r["rows"][i]["t2_speedup"]]
        sv = [r["rows"][i]["t3_cost_saving"] for r in runs
              if r["rows"][i]["t3_cost_saving"] is not None]
        ib = [r["rows"][i]["t2_within_budget"] for r in runs]
        rows.append({"steps": steps,
                     "t2_speedup": float(_np.mean(sp)) if sp else None,
                     "t2_runtime_s": runs[0]["rows"][i]["t2_runtime_s"],
                     "t3_runtime_s": runs[0]["rows"][i]["t3_runtime_s"],
                     "t3_cost_saving": float(_np.mean(sv)) if sv else None,
                     "t2_within_budget": all(ib),
                     "per_seed_speedups": sp, "per_seed_savings": sv})
    return {"table": "2+3 (auto-provisioning, mean of %d seeds)" % n_seeds,
            "arch": ARCH, "paper_speedup": 1.74,
            "paper_cost_saving": 0.388, "rows": rows,
            "per_seed": [r["rows"] for r in runs]}


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
