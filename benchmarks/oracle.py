"""Roofline-derived step-time oracle for TPU-job auto-provisioning
experiments (Tables 2/3 analog).

On a real cluster the profiler's training data comes from real runs; this
container is CPU-only, so the oracle predicts step time from the same
three-term roofline the dry-run derives, as a function of (chips, hbm_gb):

  compute    = MODEL_FLOPS * remat_factor / (chips * PEAK)
  memory     = (3 * param_bytes + act_bytes(batch, seq) ) / (chips * HBM)
  collective = fsdp gather + grad reduce-scatter bytes / (chips * ICI)
               + a per-step latency floor that grows with chip count

  t_step = max(compute, memory, collective);  t_job = steps * t_step

remat_factor rises when per-chip HBM cannot hold the no-remat working set
(less memory -> recompute). Multiplicative log-normal noise models cloud
variance (paper §5.1: caching, multi-tenancy). The oracle's FUNCTIONAL
FORM is what the paper's log-linear model must fit — deliberately not a
pure power law (collective floor), mirroring the paper's observed CPU
non-linearity.
"""
from __future__ import annotations

import math
from typing import Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS


def step_time(cfg: ArchConfig, shape: ShapeConfig, chips: float,
              hbm_gb: float, rng: Optional[np.random.Generator] = None,
              noise: float = 0.0) -> float:
    n = cfg.n_active_params()
    tokens = shape.global_batch * shape.seq_len
    param_bytes = 4.0 * cfg.n_params()
    act_bytes = 2.0 * tokens * cfg.d_model * 8       # boundary activations

    # remat need: fp32 params+moments+grads + activations must fit in the
    # usable fraction of the reservation; below that the job trains with
    # full activation recompute (4/3 compute)
    resident = 12.0 * cfg.n_params() / chips + act_bytes / chips
    budget = hbm_gb * 1e9
    remat = 1.0 if resident < 0.9 * budget else 4.0 / 3.0

    compute = 6.0 * n * tokens * remat / (chips * PEAK_FLOPS)
    memory = (3.0 * param_bytes + 4.0 * act_bytes) / (chips * HBM_BW)
    # FSDP gather + gradient reduce-scatter: every device moves ~the full
    # parameter bytes per step REGARDLESS of chip count (ring collectives)
    # — the strong-scaling wall the provisioner must respect
    coll = (2.5 * param_bytes / ICI_BW
            + 2e-3 * math.log2(max(chips, 2)))       # latency floor
    t = max(compute, memory, coll)
    if noise and rng is not None:
        t *= math.exp(rng.normal(0.0, noise))
    return t


def job_time(cfg, shape, steps: float, chips: float, hbm_gb: float,
             rng=None, noise: float = 0.0) -> float:
    return steps * step_time(cfg, shape, chips, hbm_gb, rng, noise)
