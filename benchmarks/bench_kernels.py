"""Pallas-kernel micro-benches: allclose error vs ref + µs/call.

interpret=True on CPU — numbers validate correctness and harness overhead,
NOT TPU performance (the kernels lower to Mosaic on real TPUs; their VMEM
working sets are chosen in the kernel files)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> list[dict]:
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    b, s, h, kv, d = 1, 512, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kv, d))
    v = jax.random.normal(ks[2], (b, s, kv, d))
    out = ops.flash_attention(q, k, v, interpret=True)
    want = ref.attention_ref(q, k, v)
    rows.append({
        "kernel": "flash_attention", "shape": f"{b}x{s}x{h}x{d} gqa{h//kv}",
        "max_err": float(jnp.abs(out - want).max()),
        "us_per_call_interpret": _time(
            lambda *a: ops.flash_attention(*a, interpret=True), q, k, v),
    })

    r = jax.random.normal(ks[3], (1, 256, 2, 64)) * 0.5
    kk = jax.random.normal(ks[4], (1, 256, 2, 64)) * 0.5
    vv = jax.random.normal(ks[5], (1, 256, 2, 64)) * 0.5
    logw = -jnp.exp(jax.random.uniform(ks[6], (1, 256, 2, 64),
                                       minval=-7.0, maxval=-0.7))
    u = jax.random.normal(ks[7], (2, 64)) * 0.3
    out = ops.wkv6(r, kk, vv, logw, u, interpret=True)
    want = ref.wkv6_ref(r, kk, vv, logw, u)
    rows.append({
        "kernel": "wkv6", "shape": "1x256x2x64",
        "max_err": float(jnp.abs(out - want).max()),
        "us_per_call_interpret": _time(
            lambda *a: ops.wkv6(*a, interpret=True), r, kk, vv, logw, u),
    })

    x = jax.random.normal(ks[0], (1, 256, 4, 64)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 256, 4)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (4,)) * 0.3)
    B = jax.random.normal(ks[3], (1, 256, 1, 32)) * 0.5
    C = jax.random.normal(ks[4], (1, 256, 1, 32)) * 0.5
    D = jnp.ones((4,))
    out = ops.mamba2_ssd(x, dt, A, B, C, D, interpret=True)
    want = ref.ssd_ref(x, dt, A, B, C, D)
    rows.append({
        "kernel": "mamba2_ssd", "shape": "1x256x4x64 n32",
        "max_err": float(jnp.abs(out - want).max()),
        "us_per_call_interpret": _time(
            lambda *a: ops.mamba2_ssd(*a, interpret=True),
            x, dt, A, B, C, D),
    })

    q1 = jax.random.normal(ks[5], (2, 1, 4, 64))
    kc = jax.random.normal(ks[6], (2, 1024, 2, 64))
    vc = jax.random.normal(ks[7], (2, 1024, 2, 64))
    clen = jnp.array([700, 300], jnp.int32)
    out = ops.decode_attention(q1, kc, vc, clen, interpret=True)
    want = ref.decode_attention_ref(jnp.swapaxes(q1, 1, 2)[:, :, 0],
                                    jnp.swapaxes(kc, 1, 2),
                                    jnp.swapaxes(vc, 1, 2), clen)
    rows.append({
        "kernel": "decode_attention", "shape": "2x1024x4x64",
        "max_err": float(jnp.abs(out[:, 0] - want).max()),
        "us_per_call_interpret": _time(
            lambda *a: ops.decode_attention(*a, interpret=True),
            q1, kc, vc, clen),
    })
    return rows


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))
