"""Pallas-kernel micro-benches: block-size autotuning + error vs ref.

Every shape goes through the deterministic hillclimb autotuner
(``repro.core.provision.autotune``): the row reports the *tuned* config,
its µs/call, the speedup over the kernel's MXU default, and the achieved
fraction of the family's roofline ceiling. interpret=True on CPU — the
numbers validate correctness, tuner behavior, and harness overhead, NOT
TPU performance (the kernels lower to Mosaic on real TPUs).

``--write`` regenerates the committed ``BENCH_kernels.json`` tuning
cache; ``--smoke`` is the CI gate: it re-tunes the smoke shapes and
hard-fails when a committed config diverges from the reference kernels
or stops beating the default on the current host (a stale cache).
"""
from __future__ import annotations

import argparse
import json

from repro.core.provision.autotune import (KERNELS, TuningCache,
                                           _interpret_measure, autotune_all,
                                           cache_key, default_family,
                                           max_abs_err, seed_config,
                                           shape_key)

CACHE_PATH = "BENCH_kernels.json"   # cwd-relative: CI runs at the repo root
SMOKE_FACTOR = 1.5                  # committed config vs default, noise slack


def _rows(entries: list[dict]) -> list[dict]:
    """Tuning entries -> the ``benchmarks/run.py`` row contract
    (``kernel`` / ``max_err`` / ``us_per_call_interpret``) plus the
    tuning fields."""
    return [{
        "kernel": e["kernel"],
        "shape": shape_key(e["shape"]),
        "max_err": e["max_err"],
        "us_per_call_interpret": e["us"],
        "config": e["config"],
        "default_us": e["default_us"],
        "speedup_vs_default": e["speedup_vs_default"],
        "roofline_fraction": e["roofline_fraction"],
        "candidates_measured": e["candidates_measured"],
    } for e in entries]


def run(seed: int = 0) -> list[dict]:
    """Tune the smoke shapes, one row per (kernel, shape)."""
    return _rows(autotune_all(interpret=True, seed=seed))


def check_regression(fresh: list[dict], path: str = CACHE_PATH,
                     factor: float = SMOKE_FACTOR) -> list[str]:
    """CI gate vs the committed tuning cache. For every committed entry
    of the current family: it must have been re-tuned this run (shape
    drift without ``--write`` fails), its config must still match the
    reference kernel within tolerance, and its config must still beat
    (within ``factor`` timing noise) the untuned default *measured on
    this host* — absolute µs are never compared across machines."""
    committed = TuningCache(path)
    if not committed.entries:
        return []
    family = default_family()
    tuned_keys = {cache_key(e["kernel"], e["shape"], e["family"])
                  for e in fresh}
    failures = []
    for key, old in sorted(committed.entries.items()):
        if old.get("family") != family:
            continue                 # tuned for other hardware
        if key not in tuned_keys:
            failures.append(f"{key}: committed entry not re-tuned "
                            f"(shape set drifted — rerun --write)")
            continue
        spec = KERNELS[old["kernel"]]
        args, ref_out = spec.build(old["shape"], 0)
        err = max_abs_err(spec, args, ref_out, old["config"],
                          interpret=True)
        if err > old["tol"]:
            failures.append(f"{key}: committed config diverges from ref "
                            f"(err {err:.3e} > tol {old['tol']:g})")
            continue
        default_cfg = seed_config(spec, old["shape"])
        if old["config"] == default_cfg:
            continue                 # nothing tuned away from — no timing
        measure = _interpret_measure(spec, args, interpret=True, reps=3)
        # min-of-repeats on both sides: interpret-mode wall times jitter
        # hard, and a noise spike must not fail CI
        tuned_t = min(measure(old["config"]) for _ in range(3))
        default_t = min(measure(default_cfg) for _ in range(3))
        if tuned_t > factor * default_t:
            failures.append(
                f"{key}: committed config regressed on this host "
                f"({tuned_t * 1e6:.0f}us vs default "
                f"{default_t * 1e6:.0f}us, slack {factor:g}x)")
    return failures


def _report(rows: list[dict]) -> None:
    for r in rows:
        cfg = ",".join(f"{k}={v}" for k, v in sorted(r["config"].items()))
        print(f"kernel.{r['kernel']},{r['us_per_call_interpret']:.0f},"
              f"max_err={r['max_err']:.2e}_cfg={cfg}"
              f"_speedup={r['speedup_vs_default']:.2f}x"
              f"_roofline={r['roofline_fraction']:.4f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: re-tune the smoke shapes, fail on "
                         "ref divergence or a stale committed cache")
    ap.add_argument("--write", action="store_true",
                    help=f"re-tune and update the committed {CACHE_PATH}")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.smoke:
        entries = autotune_all(interpret=True, seed=args.seed)
        _report(_rows(entries))
        failures = check_regression(entries)
        if failures:
            for f in failures:
                print(f"kernels.smoke.REGRESSION,{f}")
            raise SystemExit(1)
        print("kernels.smoke,0,ok")
    elif args.write:
        cache = TuningCache(CACHE_PATH)
        entries = autotune_all(interpret=True, seed=args.seed, cache=cache)
        cache.save()
        print(f"kernels.write,0,entries={len(entries)}_path={CACHE_PATH}")
    else:
        print(json.dumps(run(args.seed), indent=1))


if __name__ == "__main__":
    main()
