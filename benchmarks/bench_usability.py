"""Paper Tables 5/6 — usability study, mechanizable analog.

The paper measures a human running a 16-job hyperparameter sweep on raw GCP
vs through the ACAI SDK (20 % total-time / 40-87 % tracking-time
reduction). A human-subject study is out of scope; we measure the
MECHANIZABLE part: the same sweep executed (a) "manually" — hand-rolled
glue: explicit result files, hand-parsed logs, hand-maintained experiment
log, linear scan to find the best run — vs (b) through the ACAI SDK (job
submission + log-parser auto-tagging + one indexed metadata query).

Reported: bookkeeping operations (the proxy for practitioner effort the
paper bills as set-up + tracking time), tracking wall time, and total wall
time. The train fn is identical in both arms.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.acai import AcaiPlatform
from repro.core.engine.registry import JobSpec

SWEEP = [{"hidden": h, "lr": lr, "bn": bn}
         for h in (32, 64) for lr in (0.3, 0.1) for bn in (0, 1)] * 2  # 16


def _train(cfg: dict, seed: int = 0) -> float:
    """Tiny real training job; returns final accuracy."""
    k = jax.random.PRNGKey(seed + cfg["hidden"])
    k1, k2, k3 = jax.random.split(k, 3)
    w_true = jax.random.normal(k1, (16,))
    x = jax.random.normal(k2, (512, 16))
    y = (x @ w_true > 0).astype(jnp.float32)
    w1 = jax.random.normal(k3, (16, cfg["hidden"])) * 0.1
    w2 = jnp.zeros((cfg["hidden"],))

    @jax.jit
    def step(w1, w2):
        def loss(w1, w2):
            h = jnp.tanh(x @ w1)
            if cfg["bn"]:
                h = (h - h.mean(0)) / (h.std(0) + 1e-5)
            p = jax.nn.sigmoid(h @ w2)
            return -jnp.mean(y * jnp.log(p + 1e-7)
                             + (1 - y) * jnp.log(1 - p + 1e-7))
        g1, g2 = jax.grad(loss, (0, 1))(w1, w2)
        return w1 - cfg["lr"] * g1, w2 - cfg["lr"] * g2

    for _ in range(60):
        w1, w2 = step(w1, w2)
    h = jnp.tanh(x @ w1)
    if cfg["bn"]:
        h = (h - h.mean(0)) / (h.std(0) + 1e-5)
    acc = jnp.mean(((h @ w2) > 0).astype(jnp.float32) == y)
    return float(acc)


def _manual_arm(workdir: Path) -> dict:
    """Hand-rolled glue: the control group's bookkeeping."""
    ops = 0
    t0 = time.perf_counter()
    t_track = 0.0
    workdir.mkdir(parents=True, exist_ok=True)
    log_path = workdir / "experiment_log.txt"
    for i, cfg in enumerate(SWEEP):
        acc = _train(cfg, seed=i)
        tt = time.perf_counter()
        # manual bookkeeping: one result file + one log append per job
        (workdir / f"run_{i}.json").write_text(
            json.dumps({"cfg": cfg, "acc": acc}))
        ops += 1
        with log_path.open("a") as f:
            f.write(f"run {i}: cfg={cfg} acc={acc:.4f}\n")
        ops += 1
        t_track += time.perf_counter() - tt
    # manual best-run search: re-read every result file
    tt = time.perf_counter()
    best, best_acc = None, -1.0
    for i in range(len(SWEEP)):
        rec = json.loads((workdir / f"run_{i}.json").read_text())
        ops += 1
        if rec["acc"] > best_acc:
            best, best_acc = rec["cfg"], rec["acc"]
    t_track += time.perf_counter() - tt
    return {"total_s": time.perf_counter() - t0, "tracking_s": t_track,
            "bookkeeping_ops": ops, "best_acc": best_acc, "best": best}


def _acai_arm(root: Path) -> dict:
    """Treatment: the sweep through the ACAI SDK."""
    t0 = time.perf_counter()
    plat = AcaiPlatform(root)
    admin = plat.create_project(plat.admin_token, "sweep")
    proj = plat.project(admin)
    ops = 0
    for i, cfg in enumerate(SWEEP):
        def fn(workdir, job, cfg=cfg, i=i):
            acc = _train(cfg, seed=i)
            print(f"[[acai:accuracy={acc},hidden={cfg['hidden']},"
                  f"lr={cfg['lr']},bn={cfg['bn']}]]")
        plat.submit_job(admin, JobSpec(name=f"sweep-{i}", project="",
                                       user="", fn=fn))
        ops += 1          # submission is the only per-job action
    tt = time.perf_counter()
    best_id = proj.metadata.find_max("accuracy", kind="job")
    best = proj.metadata.get(best_id)
    ops += 1              # one indexed query replaces the manual scan
    t_track = time.perf_counter() - tt
    return {"total_s": time.perf_counter() - t0, "tracking_s": t_track,
            "bookkeeping_ops": ops, "best_acc": best["accuracy"],
            "best": {k: best[k] for k in ("hidden", "lr", "bn")}}


def run(tmp: str = "/tmp/acai-usability") -> dict:
    import shutil
    shutil.rmtree(tmp, ignore_errors=True)
    manual = _manual_arm(Path(tmp) / "manual")
    acai = _acai_arm(Path(tmp) / "acai")
    assert abs(manual["best_acc"] - acai["best_acc"]) < 1e-6, \
        "both arms must find the same best model"
    return {
        "table": "5/6 (usability, mechanized analog)",
        "n_jobs": len(SWEEP),
        "manual": manual, "acai": acai,
        "bookkeeping_ops_reduction":
            1 - acai["bookkeeping_ops"] / manual["bookkeeping_ops"],
        "tracking_time_reduction":
            1 - acai["tracking_s"] / max(manual["tracking_s"], 1e-9),
        "paper_tracking_reduction": "40-87%",
        "note": "human set-up/dev time is not mechanizable; this measures "
                "the bookkeeping operations + machine tracking time only",
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
