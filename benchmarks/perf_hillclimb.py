"""§Perf hillclimb driver: named variants per chosen cell, re-lowered and
re-analyzed per iteration; JSON artifacts in benchmarks/results/perf/.

Run with 512 placeholder devices:
    PYTHONPATH=src python -m benchmarks.perf_hillclimb
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import dataclasses
import json
from pathlib import Path

from repro.configs.base import get_arch, register
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_mesh
from repro.train.train_step import TrainConfig

OUT = Path("benchmarks/results/perf")


def measure(name, arch, shape, *, tcfg=None, mesh=None,
            serve_layout="fsdp"):
    r = run_cell(arch, shape, mesh=mesh, tcfg=tcfg, out_dir=None,
                 serve_layout=serve_layout, verbose=False)
    f = r["roofline"]
    row = {"variant": name, "arch": arch, "shape": shape,
           "chips": r["n_chips"],
           "compute_s": f["compute_s"], "memory_s": f["memory_s"],
           "collective_s": f["collective_s"], "dominant": f["dominant"],
           "step_time_s": f["step_time_s"],
           "useful": f["useful_flops_ratio"],
           "roofline_frac": f["roofline_fraction"],
           "coll_breakdown": f["collective_breakdown"],
           "serve_layout": serve_layout,
           "tcfg": dataclasses.asdict(tcfg) if tcfg else None}
    print(f"{name:34s} compute={f['compute_s']:7.3f} "
          f"memory={f['memory_s']:7.3f} coll={f['collective_s']:7.3f} "
          f"dom={f['dominant']:10s} roofline={f['roofline_fraction']:.3f}")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"{name}.json").write_text(json.dumps(row, indent=1))
    return row


def cell_A():
    print("== Cell A: qwen3-32b x train_4k (paper-representative) ==")
    measure("A0_baseline", "qwen3-32b", "train_4k",
            tcfg=TrainConfig(remat="full"))
    measure("A1_remat_dots", "qwen3-32b", "train_4k",
            tcfg=TrainConfig(remat="dots"))
    measure("A2_dots_bf16stream", "qwen3-32b", "train_4k",
            tcfg=TrainConfig(remat="dots", param_stream_dtype="bfloat16"))
    mesh328 = make_mesh((32, 8), ("data", "model"))
    measure("A3_dots_bf16_mesh32x8", "qwen3-32b", "train_4k",
            tcfg=TrainConfig(remat="dots", param_stream_dtype="bfloat16"),
            mesh=mesh328)
    mesh644 = make_mesh((64, 4), ("data", "model"))
    measure("A4_dots_bf16_mesh64x4", "qwen3-32b", "train_4k",
            tcfg=TrainConfig(remat="dots", param_stream_dtype="bfloat16"),
            mesh=mesh644)
    mesh1282 = make_mesh((128, 2), ("data", "model"))
    measure("A5_dots_bf16_mesh128x2", "qwen3-32b", "train_4k",
            tcfg=TrainConfig(remat="dots", param_stream_dtype="bfloat16"),
            mesh=mesh1282)
    measure("A6_dots_bf16_mesh256x1", "qwen3-32b", "train_4k",
            tcfg=TrainConfig(remat="dots", param_stream_dtype="bfloat16"),
            mesh=make_mesh((256, 1), ("data", "model")))
    measure("A7_master_bf16_mesh128x2", "qwen3-32b", "train_4k",
            tcfg=TrainConfig(remat="dots", master_weights=True),
            mesh=mesh1282)


def cell_B():
    print("== Cell B: llama4-scout x train_4k (most collective-bound) ==")
    measure("B0_baseline", "llama4-scout-17b-a16e", "train_4k",
            tcfg=TrainConfig(remat="full"))
    measure("B1_dots_bf16stream", "llama4-scout-17b-a16e", "train_4k",
            tcfg=TrainConfig(remat="dots", param_stream_dtype="bfloat16"))
    base = get_arch("llama4-scout-17b-a16e")
    fused = dataclasses.replace(
        base, name="llama4-scout-fused",
        moe=dataclasses.replace(base.moe, fuse_shared=True))
    register(fused)
    measure("B2_fused_shared", "llama4-scout-fused", "train_4k",
            tcfg=TrainConfig(remat="dots", param_stream_dtype="bfloat16"))
    mesh328 = make_mesh((32, 8), ("data", "model"))
    measure("B3_fused_mesh32x8", "llama4-scout-fused", "train_4k",
            tcfg=TrainConfig(remat="dots", param_stream_dtype="bfloat16"),
            mesh=mesh328)
    measure("B4_fused_mesh64x4", "llama4-scout-fused", "train_4k",
            tcfg=TrainConfig(remat="dots", param_stream_dtype="bfloat16"),
            mesh=make_mesh((64, 4), ("data", "model")))
    measure("B6_master_mesh32x8", "llama4-scout-fused", "train_4k",
            tcfg=TrainConfig(remat="dots", master_weights=True),
            mesh=mesh328)


def cell_C():
    print("== Cell C: qwen3-32b x decode_32k (serving latency) ==")
    measure("C0_baseline_fsdp", "qwen3-32b", "decode_32k")
    measure("C2_resident_tp_only", "qwen3-32b", "decode_32k",
            serve_layout="resident")
    import repro.models.blocks as B
    B.CACHE_INSERT_IMPL = "scatter"
    measure("C3_scatter_insert", "qwen3-32b", "decode_32k",
            serve_layout="resident")
    B.CACHE_INSERT_IMPL = "onehot"


if __name__ == "__main__":
    import sys
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "A"):
        cell_A()
    if which in ("all", "B"):
        cell_B()
    if which in ("all", "C"):
        cell_C()
