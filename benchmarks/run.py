"""Benchmark harness — one benchmark per paper table (+ kernel µbenches and
the roofline collation). Prints ``name,us_per_call,derived`` CSV lines per
the repo contract, then writes a JSON blob with the full results.

NOTE: the dry-run sweep (multi-pod compiles) is NOT run from here — it
needs 512 placeholder devices (run ``python -m repro.launch.dryrun --all``);
this harness only COLLATES its JSON artifacts if present.
"""
from __future__ import annotations

import json
import time


def main() -> None:
    results = {}
    t0 = time.perf_counter()

    from benchmarks import bench_table1
    r1 = bench_table1.run()
    results["table1_runtime_prediction"] = r1
    print(f"table1.loglinear_l1,{r1['loglinear_l1_s']*1e6:.0f},"
          f"variance_explained={r1['variance_explained']:.4f}")
    print(f"table1.averaging_l1,{r1['averaging_l1_s']*1e6:.0f},baseline")

    from benchmarks import bench_table23
    r23 = bench_table23.run_multi()
    results["table23_autoprovision"] = r23
    for row in r23["rows"]:
        sp = row.get("t2_speedup")
        sv = row.get("t3_cost_saving")
        print(f"table2.steps{row['steps']},"
              f"{(row['t2_runtime_s'] or 0)*1e6:.0f},"
              f"speedup={sp:.2f}x_paper=1.74x" if sp else
              f"table2.steps{row['steps']},0,infeasible")
        print(f"table3.steps{row['steps']},"
              f"{(row['t3_runtime_s'] or 0)*1e6:.0f},"
              f"cost_saving={sv*100:.1f}%_paper=38.8%" if sv is not None
              else f"table3.steps{row['steps']},0,infeasible")

    from benchmarks import bench_usability
    ru = bench_usability.run()
    results["table56_usability"] = ru
    print(f"usability.manual,{ru['manual']['total_s']*1e6:.0f},"
          f"ops={ru['manual']['bookkeeping_ops']}")
    print(f"usability.acai,{ru['acai']['total_s']*1e6:.0f},"
          f"ops={ru['acai']['bookkeeping_ops']},"
          f"tracking_cut={ru['tracking_time_reduction']*100:.0f}%")

    from benchmarks import bench_scheduler
    rs = bench_scheduler.run()
    results["scheduler"] = rs
    bench_scheduler.report(rs)

    from benchmarks import bench_kernels
    rk = bench_kernels.run()
    results["kernels"] = rk
    for row in rk:
        print(f"kernel.{row['kernel']},{row['us_per_call_interpret']:.0f},"
              f"max_err={row['max_err']:.2e}")

    try:
        from benchmarks import roofline_sweep
        rows = roofline_sweep.load()
        if rows:
            results["roofline_summary"] = roofline_sweep.summary(rows)
            s = results["roofline_summary"]
            print(f"roofline.cells,"
                  f"{(time.perf_counter()-t0)*1e6:.0f},"
                  f"ok_single={s['cells_ok_single']}"
                  f"_ok_multi={s['cells_ok_multi']}_na={s['cells_na']}")
    except Exception as e:  # noqa: BLE001
        print(f"roofline.collate,0,skipped:{e!r}")

    print(f"total.wall,{(time.perf_counter()-t0)*1e6:.0f},seconds="
          f"{time.perf_counter()-t0:.1f}")
    with open("bench_results.json", "w") as f:
        json.dump(results, f, indent=1, default=str)


if __name__ == "__main__":
    main()
