"""Scheduler benchmark — policies and placement on finite cluster capacity.

Two scenarios, both on the deterministic virtual clock:

1. **Policy** (the PR-1 workload, now open-loop): a mixed fleet — a large
   majority of small, short profiling jobs (the auto-provisioner's
   exploration grids) sharing capacity with a minority of big, long
   training jobs — arrives as a Poisson process (or a replayed trace via
   ``--trace``) on a 16-vCPU cluster, FIFO vs fair-share + EASY backfill.
   Reported per policy: makespan, mean queue wait, and bounded-slowdown
   p50/p95/p99 (slowdown = (wait + runtime) / max(runtime, tau)) — tail
   latency, not just means.

2. **Heterogeneous pools** (this PR, the in-repo analog of the paper's
   §4.2 auto-provisioning headline): the same mix on a CPU pool + a TPU
   pool, where training jobs run ~5x faster on TPU slices (and cheaper
   per job) while short profiling jobs pay a TPU startup tax. Three
   placements over identical fleets: ``single`` (everything on a
   price-equivalent CPU-only cluster — the pre-pools engine), ``random``
   (both pools, uniform pool choice), and ``placed`` (profiler-fed
   cost/speed scoring). Profiler-fed placement must beat both baselines
   on makespan AND total cost; per-pool utilization is recorded.

An auditing cluster proves capacity is never oversubscribed on any
dimension of any pool. Emits ``BENCH_scheduler.json`` so future PRs have
a perf trajectory. ``--smoke`` runs tiny fleets (CI regression gate)
without touching the JSON.

The **chaos** scenario is the fault-tolerance layer's exit criterion as
a benchmark: one fleet, one seeded :class:`FaultPlan` (node kills,
transient job failures, stragglers on the virtual clock), run twice —
retry budgets + crash-loop quarantine ON vs OFF. Hard gates: goodput
(finished declared work per makespan second) with the layer on is >=
1.3x the no-retry run's, every job terminates, no job exceeds its retry
budget, every crash-looping job quarantines before burning its full
budget, and a run with an attached-but-inert injector is bit-identical
to one with no injector at all (the golden-trace guarantee).

The **recovery** scenario is the durable-control-plane exit criterion as
a benchmark: a subprocess drives the crash drill's seeded fleet, the
bench SIGKILLs it mid-run (polling the drill's heartbeat file for the
kill moment), then recovers in-process and drains the remainder. Hard
gates: the final states match an uninterrupted golden run of the same
fleet bit-for-bit, no job is lost or settled twice, and the capacity
books balance (zero release underflow). ``recovery_wall_s`` — the
snapshot+journal replay time — is the recorded perf number.
"""
from __future__ import annotations

import argparse
import copy
import json
import math
import os
import signal
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.engine.cluster import Cluster
from repro.core.engine.events import EventBus
from repro.core.engine.faults import FaultInjector, FaultPlan
from repro.core.engine.launcher import VirtualRunner
from repro.core.engine.lifecycle import TERMINAL_STATES, JobState
from repro.core.engine.monitor import JobMonitor
from repro.core.engine.placement import Placement, TransferCostModel
from repro.core.engine.registry import (GangSpec, JobRegistry, JobSpec,
                                        RetryPolicy)
from repro.core.engine.scheduler import Scheduler
from repro.core.provision.elastic import ElasticController, PoolPolicy
from repro.core.provision.pricing import (CPU_PRICING, ChipScaledPricing,
                                          Pricing, ResourceDim,
                                          spot_pricing)
from repro.core.provision.profiler import CommandTemplate, Profiler
from repro.roofline.prior import HardwareSpec, RooflinePrior

N_JOBS = 5000
N_USERS = 8
NODES = 2               # 16 vCPU / 16 GB total — heavy contention
ARRIVAL_RATE = 0.04     # Poisson arrivals per second (open-loop overload)
SLOWDOWN_TAU = 10.0     # bounded-slowdown floor (short-job guard)

# -- heterogeneous fleet ------------------------------------------------
HETERO_JOBS = 3000
CPU_NODES = 4           # 32 vCPU / 32 GB
TPU_CHIPS = 64
TPU_STARTUP = 60.0      # pod provisioning + compile tax per job, seconds
TPU_SPEED = 6.0         # speedup of 8 TPU chips over the job's CPU shape

# bench-local TPU slice pricing: small pod slices priced so a training
# job's faster TPU run is also the cheaper one (the cost/speed frontier
# the placement layer is supposed to find); profiling jobs still lose on
# TPU because the startup tax dominates their runtime.
TPU_BENCH_PRICING = ChipScaledPricing([
    ResourceDim("chips", 8, TPU_CHIPS, 0.10, (8, 16, 32, 64)),
    ResourceDim("hbm_gb", 2, 16, 0.005, (2, 4, 8, 16)),
], family="tpu")

# -- cold-start feedback scenario ----------------------------------------
FEEDBACK_JOBS = 2000
FEEDBACK_RATE = 0.25        # arrivals/s: spread so early completions can
                            # inform the ranking of later arrivals
PRIOR_SPEED = 4.0           # the prior's believed TPU speedup (true: 6)
PRIOR_STARTUP = 30.0        # the prior's believed startup tax (true: 60)
WORK_UNIT_FLOPS = 1e9       # declared work-seconds -> modelled FLOPs
FEEDBACK_MIN_SPEEDUP = 1.2  # hard gate vs declared-duration placement
FEEDBACK_ORACLE_GAP = 1.25  # hard gate: within 25% of the oracle fit

# -- elastic + spot scenario ---------------------------------------------
ELASTIC_JOBS = 1500
ELASTIC_RATE = 0.009        # ~115% of the static config's capacity: the
                            # static pool builds a backlog it must drain
                            # past the last arrival, while the elastic
                            # deployment's spot capacity absorbs it
ELASTIC_MAX_NODES = 4       # on-demand pool: controller range [1, 4]
SPOT_NODES = 4              # spot pool: fixed capacity, reclaimable
SPOT_DISCOUNT = 0.6         # spot price = 40% of on-demand
ELASTIC_CKPT = 60.0         # checkpoint interval: the lost-work bound
ELASTIC_RECLAIM_MEAN = 1800.0   # mean seconds between spot reclamations
SPOT_OUTAGE = 900.0         # a reclaimed spot node stays gone this long
ELASTIC_STARVE = 300.0      # preempt for a head starved past this
ELASTIC_CTL_EVERY = 120.0   # provisioning-controller cadence

# -- scale scenario (50k jobs / 64 users / 3 pools) ----------------------
SCALE_JOBS = 50_000
SCALE_USERS = 64
GPU_CHIPS = 32
GPU_BENCH_PRICING = Pricing([
    ResourceDim("gpu", 1, GPU_CHIPS, 0.08, (1, 2, 4, 8)),
    ResourceDim("vram_gb", 8, 80, 0.002, (8, 16, 40, 80)),
], family="gpu")

# -- gang scenario (8-pod training gangs vs 1-pod sweep jobs) ------------
GANG_JOBS = 600
GANG_PODS = 8               # pods per training gang (4 GPUs per pod)
GANG_POD_GPUS = 4.0
GANG_FRACTION = 0.03        # gang share of the open-loop fleet body
GANG_LOAD = 0.4             # open-loop target load across both pools —
                            # low enough that both pools usually have
                            # room, so jobs get their top-RANKED pool and
                            # the A/B difference is the placement choice,
                            # not greedy same-pool spill under saturation
GANG_WAVE = 3               # final training wave: 3 gangs, 60s apart
GANG_NODES = 16             # nodes per pool, 8 GPUs each
# interconnect islands: "pod" hosts a whole gang close; "island" can only
# keep 2 pods on one island, so a close-topology gang spread there pays
# an all-reduce slowdown (the oracle's ground truth below)
GANG_CLOSE = {"pod": GANG_PODS, "island": 2}
GANG_SPREAD_SLOWDOWN = 3.0  # runtime inflation at full spread
GANG_INTERCONNECT_W = 4.0   # placement's modelled spread penalty weight
GANG_POD_PRICING = Pricing([
    ResourceDim("gpu", 1, 8, 0.20, (1, 2, 4, 8))], family="pod")
GANG_ISLAND_PRICING = Pricing([
    ResourceDim("gpu", 1, 8, 0.10, (1, 2, 4, 8))], family="island")

# -- kill -9 recovery scenario (durable control plane) --------------------
RECOVERY_JOBS = 5000        # drill fleet size for the full bench
RECOVERY_KILL_FRAC = 0.3    # SIGKILL near 30% of completions
RECOVERY_SEED = 7

# -- thundering-herd scenario (one user map()-fans a sweep) ---------------
HERD_JOBS = 10_000          # the fanning user's burst, all at t=0
HERD_OTHERS = 63            # background users sharing the cluster
HERD_P95_BOUND = 300.0      # fair-share gate on the others' p95 wait

# -- chaos scenario (fault-tolerance layer under seeded faults) -----------
CHAOS_JOBS = 600
CHAOS_SEED = 13
CHAOS_RATE = 0.04           # arrivals/s on a 32-vCPU cluster: ~50% load,
                            # so the retry run's extra incarnations fit
                            # without the backlog dominating makespan
CHAOS_NODES = 4
CHAOS_NODE_SHAPE = {"vcpu": 8.0, "mem_mb": 8192.0}
CHAOS_DOOMED = 5            # crash-looping jobs (quarantine exercise)
CHAOS_MAX_RETRIES = 3
CHAOS_QUARANTINE_K = 3      # consecutive fatal failures -> QUARANTINED
CHAOS_GOODPUT_GATE = 1.3    # hard gate: retry goodput vs no-retry
# the seeded fault schedule both configurations suffer identically:
# transient MTBF is set aggressive enough that the no-retry run loses
# ~1/3 of its work — the layer under test has something real to recover
CHAOS_PLAN = dict(node_mtbf_s=3000.0, transient_mtbf_s=60.0,
                  straggler_mtbf_s=400.0, straggler_factor=4.0,
                  start=60.0, max_node_failures=2)


class AuditingCluster(Cluster):
    """Records the reservation high-water mark per dimension, plus
    reservations that oversubscribed capacity *at reserve time* — the
    invariant that stays meaningful on an elastic pool, where comparing
    an old high-water mark against a post-shrink capacity would flag
    legitimate (drained) over-commit as a bug."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.high_water = {n: 0.0 for n in self.capacity}
        self.reserve_violations = 0

    def reserve(self, job_id, resources):
        req = super().reserve(job_id, resources)
        for n in self.capacity:
            self.high_water[n] = max(self.high_water[n], self.used[n])
            if self.used[n] > self.capacity[n] + 1e-9:
                self.reserve_violations += 1
        return req

    @property
    def oversubscribed(self) -> bool:
        return any(self.high_water[n] > self.capacity[n] + 1e-9
                   for n in self.capacity)


class GangAuditingCluster(AuditingCluster):
    """AuditingCluster + the gang invariant: ``reserve_gang`` either holds
    ALL n pods' charge or leaves the books untouched — audited against
    the live usage before/after every call, success or failure. A nonzero
    ``partial_gang_holds`` fails the scenario's hard gate."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.gang_reserves = 0
        self.partial_gang_holds = 0

    def reserve_gang(self, job_id, per_pod, n_pods):
        before = dict(self.used)
        pod = self.charge(per_pod)
        try:
            agg = super().reserve_gang(job_id, per_pod, n_pods)
        except Exception:
            if any(abs(self.used.get(n, 0.0) - before.get(n, 0.0)) > 1e-9
                   for n in set(before) | set(self.used)):
                self.partial_gang_holds += 1    # failed reserve left charge
            raise
        self.gang_reserves += 1
        held = self.held(job_id) or {}
        if any(abs(held.get(n, 0.0) - amt * n_pods) > 1e-9
               for n, amt in pod.items()):
            self.partial_gang_holds += 1        # held != n_pods x per-pod
        for n in self.capacity:
            self.high_water[n] = max(self.high_water[n], self.used[n])
            if self.used[n] > self.capacity[n] + 1e-9:
                self.reserve_violations += 1
        return agg


class RandomPlacement(Placement):
    """Uniform pool choice among eligible pools — the dumb baseline."""

    def __init__(self, pools, *, seed: int = 0, **kw):
        super().__init__(pools, **kw)
        self._rng = np.random.default_rng(seed)

    def rank(self, spec, options, parent_pools=frozenset()):
        names = sorted(options)
        self._rng.shuffle(names)
        return names


# -- fleets -------------------------------------------------------------
def make_fleet(seed: int = 0, n_jobs: int = N_JOBS) -> list[JobSpec]:
    rng = np.random.default_rng(seed)
    fleet = []
    for i in range(n_jobs):
        user = f"u{int(rng.integers(N_USERS))}"
        if rng.random() < 0.9:       # profiling job: small + short
            spec = JobSpec(
                name=f"prof-{i}", project="bench", user=user,
                duration=float(rng.uniform(5.0, 60.0)),
                resources={"vcpu": float(rng.choice([0.5, 1.0, 2.0])),
                           "mem_mb": float(rng.choice([512, 1024, 2048]))})
        else:                        # training job: big + long
            spec = JobSpec(
                name=f"train-{i}", project="bench", user=user,
                duration=float(rng.uniform(300.0, 900.0)),
                resources={"vcpu": 8.0, "mem_mb": 8192.0})
        fleet.append(spec)
    return fleet


def make_hetero_fleet(seed: int = 0,
                      n_jobs: int = HETERO_JOBS) -> list[JobSpec]:
    """Pool-flexible mix: every job declares a CPU and a TPU shape;
    ``args['work']`` is its runtime on the CPU shape (the oracle and the
    profiler's ground truth)."""
    rng = np.random.default_rng(seed)
    fleet = []
    for i in range(n_jobs):
        user = f"u{int(rng.integers(N_USERS))}"
        if rng.random() < 0.85:      # profiling job: startup tax dominates
            work = float(rng.uniform(5.0, 60.0))
            spec = JobSpec(
                name=f"prof-{i}", project="bench", user=user,
                template="work", args={"work": work},
                pool_resources={
                    "cpu": {"vcpu": float(rng.choice([0.5, 1.0, 2.0])),
                            "mem_mb": float(rng.choice([512, 1024, 2048]))},
                    "tpu": {"chips": 8.0, "hbm_gb": 2.0}})
        else:                        # training job: TPU-friendly
            work = float(rng.uniform(1200.0, 3600.0))
            spec = JobSpec(
                name=f"train-{i}", project="bench", user=user,
                template="work", args={"work": work},
                pool_resources={
                    "cpu": {"vcpu": 8.0, "mem_mb": 8192.0},
                    "tpu": {"chips": float(rng.choice([8, 16])),
                            "hbm_gb": 4.0}})
        fleet.append(spec)
    return fleet


def hetero_oracle(job) -> float:
    """Ground-truth runtime: CPU runs at the declared work; a TPU slice
    amortizes a startup tax against a chip-scaled speedup."""
    work = job.spec.args["work"]
    if job.pool == "tpu":
        chips = float(job.spec.resources.get("chips", 8))
        return TPU_STARTUP + work * 8.0 / (TPU_SPEED * chips)
    return work


def fit_hetero_profiler() -> Profiler:
    """Per-pool runtime models ('work@cpu' / 'work@tpu') fit offline from
    the oracle's ground truth — the profiler pathway placement scores
    through (log-linear, so the TPU model is an approximation; placement
    only needs the ranking to survive the fit error)."""
    prof = Profiler(engine=None)
    works = [5, 10, 20, 40, 60, 120, 600, 1200, 2400, 3600]
    cpu_t = CommandTemplate(
        "work@cpu", {"work": works},
        {"vcpu": [0.5, 1.0, 2.0, 8.0], "mem_mb": [512, 2048, 8192]})
    grid = cpu_t.grid()
    prof.fit_offline(cpu_t, grid, [c["work"] for c in grid])
    tpu_t = CommandTemplate(
        "work@tpu", {"work": works},
        {"chips": [8.0, 16.0], "hbm_gb": [2.0, 4.0]})
    grid = tpu_t.grid()
    prof.fit_offline(
        tpu_t, grid,
        [TPU_STARTUP + c["work"] * 8.0 / (TPU_SPEED * c["chips"])
         for c in grid])
    return prof


# -- decision-equivalence replay harness --------------------------------
def decision_trace(n_jobs: int = 500, seed: int = 7, *,
                   policy: str = "fair", backfill: bool = True,
                   hetero: bool = False, kill_every: int = 0,
                   quota_k: int = 16, preemption: bool = False,
                   starvation_threshold: float = 300.0,
                   checkpoint_interval: float | None = None,
                   priority_every: int = 0,
                   transfer_costs: TransferCostModel | None = None
                   ) -> list[list]:
    """The scheduler's decision sequence on a fixed-seed fleet:
    ``[[job name, pool], ...]`` in launch order. A perf refactor of the
    dispatch core must reproduce this trace bit-identically (same launch
    order, same pool assignment) — the tier-1 equivalence test replays it
    against ``tests/data/golden_trace_*.json`` recorded before the
    refactor. ``kill_every=k`` kills the job that arrived 15 submissions
    earlier at every k-th arrival (if not yet terminal), so the trace
    also pins kill-path bookkeeping. With ``preemption=True`` (plus
    ``priority_every=p`` stamping every p-th job high priority so heads
    actually starve) a preempted job's relaunch appears as a second
    trace entry — the preemption-policy golden pins victim selection and
    checkpoint-resume scheduling too."""
    registry = JobRegistry()
    bus = EventBus()
    if hetero:
        fleet = make_hetero_fleet(seed, n_jobs)
        arrivals = [(0.0, s) for s in fleet]
        placement = Placement(
            {"cpu": _cpu_pool(CPU_NODES), "tpu": _tpu_pool()},
            pricing={"cpu": CPU_PRICING, "tpu": TPU_BENCH_PRICING},
            objective="cost", transfer_costs=transfer_costs)
        placement.use_profiler(fit_hetero_profiler())
        cluster = None
        oracle = hetero_oracle
    else:
        fleet = make_fleet(seed, n_jobs)
        if priority_every:
            for i, spec in enumerate(fleet):
                if i % priority_every == 0:
                    spec.priority = 10
        arrivals = poisson_arrivals(fleet, ARRIVAL_RATE, seed)
        placement = None
        cluster = AuditingCluster(
            {n: max(d.values) * NODES for n, d in CPU_PRICING.dims.items()},
            {n: d.minimum for n, d in CPU_PRICING.dims.items()})
        oracle = None
    runner = VirtualRunner(registry, bus, oracle=oracle,
                           checkpoint_interval=checkpoint_interval)
    sched = Scheduler(registry, runner, bus, quota_k=quota_k,
                      cluster=cluster, placement=placement,
                      policy=policy, backfill=backfill,
                      preemption=preemption,
                      starvation_threshold=starvation_threshold)
    trace: list[list] = []
    orig_launch = runner.launch

    def launch(job):
        trace.append([job.spec.name, job.pool])
        orig_launch(job)
    runner.launch = launch

    submitted: list = []
    for i, (t, spec) in enumerate(arrivals):
        while True:
            nc = runner.next_completion()
            if nc is None or nc > t:
                break
            runner.step()
        runner.advance_to(t)
        job = registry.submit(JobSpec(**spec.__dict__))
        submitted.append(job.job_id)
        sched.submit(job)
        if kill_every and i % kill_every == 0 and i >= 15:
            victim = submitted[i - 15]
            if registry.get(victim).state not in TERMINAL_STATES:
                sched.kill(victim)
    sched.run_to_completion()
    return trace


# -- arrival processes --------------------------------------------------
def poisson_arrivals(fleet: list[JobSpec], rate: float,
                     seed: int = 0) -> list[tuple[float, JobSpec]]:
    """Open-loop Poisson arrivals on the virtual clock (None rate =>
    closed fleet, everything at t=0)."""
    if not rate:
        return [(0.0, spec) for spec in fleet]
    rng = np.random.default_rng(seed + 1000)
    times = np.cumsum(rng.exponential(1.0 / rate, size=len(fleet)))
    return list(zip(times.tolist(), fleet))


def trace_arrivals(path: str) -> list[tuple[float, JobSpec]]:
    """Trace-replay hook: JSONL rows
    ``{"t": sec, "duration": sec, "name"?, "user"?, "resources"?}``
    become the arrival process instead of the synthetic fleet."""
    out = []
    with open(path) as f:
        for i, line in enumerate(f):
            if not line.strip():
                continue
            row = json.loads(line)
            out.append((float(row["t"]), JobSpec(
                name=row.get("name", f"trace-{i}"), project="bench",
                user=row.get("user", "u0"),
                duration=float(row["duration"]),
                resources=row.get("resources", {}))))
    out.sort(key=lambda p: p[0])
    return out


# -- simulation core ----------------------------------------------------
def simulate(arrivals: list[tuple[float, JobSpec]], *,
             cluster=None, placement=None, pricing=None, oracle=None,
             policy: str = "fair", backfill: bool = True,
             quota_k: int = 16, backfill_depth: int = 50,
             snapshot_interval: float = 3600.0,
             user_waits: dict | None = None,
             feedback_profiler: Profiler | None = None) -> dict:
    """Drive one scheduler configuration through an arrival process on
    the virtual clock; returns metrics incl. slowdown percentiles.
    Scheduler snapshots are coalesced to one per virtual hour by default
    (pure observability — decisions are unaffected).
    ``feedback_profiler`` closes the measurement loop: it subscribes to
    the runner's FINISHED events, so every completion refits the per-pool
    model the placement under test is scoring with."""
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus, oracle=oracle, pricing=pricing)
    monitor = JobMonitor(bus)
    if feedback_profiler is not None:
        feedback_profiler.attach_feedback(bus, registry)
    sched = Scheduler(registry, runner, bus, quota_k=quota_k,
                      cluster=cluster, placement=placement,
                      policy=policy, backfill=backfill,
                      backfill_depth=backfill_depth,
                      snapshot_interval=snapshot_interval)
    starts: dict[str, float] = {}
    orig_launch = runner.launch

    def launch(job):
        starts[job.job_id] = runner.now
        orig_launch(job)
    runner.launch = launch

    submitted: dict[str, float] = {}
    t0 = time.perf_counter()
    for t, spec in arrivals:
        while True:
            nc = runner.next_completion()
            if nc is None or nc > t:
                break
            runner.step()
        runner.advance_to(t)
        # shallow spec copy: the scheduler rebinds (never mutates in
        # place) spec.resources at launch, so sharing the field dicts
        # with the template is safe and skips the dataclass re-init
        job = registry.submit(copy.copy(spec))
        submitted[job.job_id] = t
        sched.submit(job)
    sched.run_to_completion()
    wall = time.perf_counter() - t0

    jobs = registry.all_jobs()
    finished = sum(1 for j in jobs if j.state == JobState.FINISHED)
    assert finished == len(arrivals), f"{finished}/{len(arrivals)} finished"
    pools = sched.pools
    oversub = any(getattr(cl, "oversubscribed", False)
                  for cl in pools.values())
    slow = []
    for jid, t_sub in submitted.items():
        j = registry.get(jid)
        wait = starts[jid] - t_sub
        rt = j.runtime or 0.0
        slow.append(max(1.0, (wait + rt) / max(rt, SLOWDOWN_TAU)))
        if user_waits is not None:
            user_waits.setdefault(j.spec.user, []).append(wait)
    p50, p95, p99 = np.percentile(slow, [50, 95, 99])
    makespan = runner.now
    total_cost = sum(j.cost or 0.0 for j in jobs)
    return {
        "policy": f"{policy}+backfill" if backfill else policy,
        "n_jobs": len(arrivals),
        "makespan_s": makespan,
        "mean_queue_wait_s": sched.mean_queue_wait(),
        "slowdown_p50": float(p50),
        "slowdown_p95": float(p95),
        "slowdown_p99": float(p99),
        "throughput_jobs_per_hour": len(arrivals) / (makespan / 3600.0),
        "backfilled": sched.stats["backfilled"],
        "placed_by_pool": dict(sched.stats["placed_by_pool"]),
        "pool_utilization": {p: monitor.utilization_by_pool().get(p, {})
                             for p in pools},
        "total_cost": total_cost,
        "oversubscribed": oversub,
        "wall_s": wall,
        "sched_events_per_s": len(arrivals) * 2 / max(wall, 1e-9),
    }


# -- scenario 1: policies under open-loop arrivals ----------------------
def run_policy(arrivals, policy: str, backfill: bool,
               repeats: int = 3) -> dict:
    """One policy over the arrival process. The simulation is fully
    deterministic (identical decisions every run), so the scheduler-
    throughput measurement keeps the minimum-wall repeat — the standard
    guard against scheduler-external noise on shared CI hardware."""
    best = None
    for _ in range(max(1, repeats)):
        cluster = AuditingCluster(
            {n: max(d.values) * NODES for n, d in CPU_PRICING.dims.items()},
            {n: d.minimum for n, d in CPU_PRICING.dims.items()})
        res = simulate(arrivals, cluster=cluster, pricing=CPU_PRICING,
                       policy=policy, backfill=backfill)
        res["peak_vcpu"] = cluster.high_water["vcpu"]
        res["capacity_vcpu"] = cluster.capacity["vcpu"]
        if best is None or res["wall_s"] < best["wall_s"]:
            best = res
    return best


# -- scenario 2: heterogeneous pools ------------------------------------
def _cpu_pool(nodes: int) -> AuditingCluster:
    return AuditingCluster(
        {n: max(d.values) * nodes for n, d in CPU_PRICING.dims.items()},
        {n: d.minimum for n, d in CPU_PRICING.dims.items()}, name="cpu")


def _tpu_pool() -> AuditingCluster:
    return AuditingCluster(
        {"chips": float(TPU_CHIPS), "hbm_gb": 4.0 * TPU_CHIPS},
        {"chips": 8.0, "hbm_gb": 2.0}, name="tpu")


def _single_pool_equiv_nodes() -> int:
    """CPU nodes whose hourly price matches the heterogeneous deployment
    (CPU pool + TPU pool) — the price-equivalent homogeneous baseline."""
    cpu_node_rate = CPU_PRICING.hourly_rate(
        {n: max(d.values) for n, d in CPU_PRICING.dims.items()})
    tpu_pool_rate = TPU_BENCH_PRICING.hourly_rate(
        {"chips": float(TPU_CHIPS), "hbm_gb": 2.0})
    return CPU_NODES + max(1, math.ceil(tpu_pool_rate / cpu_node_rate))


def run_hetero(n_jobs: int = HETERO_JOBS, seed: int = 0,
               quota_k: int = 64) -> dict:
    fleet = make_hetero_fleet(seed, n_jobs)
    arrivals = [(0.0, s) for s in fleet]
    catalog = {"cpu": CPU_PRICING, "tpu": TPU_BENCH_PRICING}
    prof = fit_hetero_profiler()
    single_nodes = _single_pool_equiv_nodes()

    # single CPU-only pool, price-equivalent hardware (the old engine)
    single = simulate(
        arrivals, pricing=catalog, oracle=hetero_oracle, quota_k=quota_k,
        placement=Placement({"cpu": _cpu_pool(single_nodes)},
                            pricing=catalog))

    # both pools, uniform pool choice
    random_p = simulate(
        arrivals, pricing=catalog, oracle=hetero_oracle, quota_k=quota_k,
        placement=RandomPlacement(
            {"cpu": _cpu_pool(CPU_NODES), "tpu": _tpu_pool()},
            pricing=catalog, seed=seed))

    # both pools, profiler-fed cost/speed scoring
    placement = Placement({"cpu": _cpu_pool(CPU_NODES), "tpu": _tpu_pool()},
                          pricing=catalog, objective="cost")
    placement.use_profiler(prof)
    placed = simulate(
        arrivals, pricing=catalog, oracle=hetero_oracle, quota_k=quota_k,
        placement=placement)

    out = {
        "fleet": {"n_jobs": n_jobs, "n_users": N_USERS,
                  "cpu_nodes": CPU_NODES, "tpu_chips": TPU_CHIPS,
                  "single_pool_cpu_nodes": single_nodes},
        "single_pool": single,
        "random_pool": random_p,
        "profiler_placed": placed,
        "makespan_speedup_vs_single":
            single["makespan_s"] / placed["makespan_s"],
        "makespan_speedup_vs_random":
            random_p["makespan_s"] / placed["makespan_s"],
        "cost_saving_vs_single":
            1.0 - placed["total_cost"] / single["total_cost"],
        "cost_saving_vs_random":
            1.0 - placed["total_cost"] / random_p["total_cost"],
    }
    for name, r in (("single", single), ("random", random_p),
                    ("placed", placed)):
        assert not r["oversubscribed"], f"hetero.{name} oversubscribed"
    # the headline invariant: profiler-fed placement wins BOTH axes
    assert placed["makespan_s"] < single["makespan_s"], "no speedup"
    assert placed["makespan_s"] < random_p["makespan_s"], "random faster"
    assert placed["total_cost"] < single["total_cost"], "no cost saving"
    assert placed["total_cost"] < random_p["total_cost"], "random cheaper"
    return out


# -- scenario 2b: cold-start prior + launcher feedback -------------------
def _feedback_prior() -> RooflinePrior:
    """Roofline prior for the 'work' template, deliberately
    mis-calibrated: it believes 2/3 of the true TPU speedup
    (``PRIOR_SPEED`` vs ``TPU_SPEED``) and half the true startup tax.
    Declared work-seconds map to FLOPs at ``WORK_UNIT_FLOPS``; the CPU
    family retires exactly that rate, a TPU slice scales with its chip
    count. The point of the scenario is that even a wrong-by-constants
    prior routes the fleet correctly on a cold cluster, and launcher
    feedback then corrects the constants."""
    cpu = HardwareSpec("cpu", peak_flops=WORK_UNIT_FLOPS, hbm_bw=1.0)
    tpu = HardwareSpec(
        "tpu", peak_flops=WORK_UNIT_FLOPS * PRIOR_SPEED / 8.0,
        hbm_bw=1.0, startup_s=PRIOR_STARTUP,
        scale_dim="chips", ref_chips=1.0)
    return RooflinePrior({"cpu": cpu, "tpu": tpu}).register(
        "work", flops=lambda cfg: cfg["work"] * WORK_UNIT_FLOPS)


def run_feedback(n_jobs: int = FEEDBACK_JOBS, seed: int = 0,
                 quota_k: int = 64) -> dict:
    """Cold-cluster placement quality, four estimator configurations on
    identical Poisson arrivals: ``declared`` (user-declared CPU-shape
    durations — no profiler), ``prior_only`` (roofline prior, loop open),
    ``prior_feedback`` (prior + online refit from every FINISHED event),
    and ``oracle`` (offline fit from ground truth — the quality ceiling).
    Hard gates: prior+feedback beats declared by
    ``FEEDBACK_MIN_SPEEDUP`` on makespan and lands within
    ``FEEDBACK_ORACLE_GAP`` of the oracle."""
    fleet = make_hetero_fleet(seed, n_jobs)
    arrivals = poisson_arrivals(fleet, FEEDBACK_RATE, seed)
    catalog = {"cpu": CPU_PRICING, "tpu": TPU_BENCH_PRICING}

    def pools():
        return {"cpu": _cpu_pool(CPU_NODES), "tpu": _tpu_pool()}

    def sim(placement, prof=None):
        res = simulate(arrivals, pricing=catalog, oracle=hetero_oracle,
                       quota_k=quota_k, placement=placement,
                       feedback_profiler=prof)
        res["prediction_sources"] = dict(placement.stats)
        return res

    # the declared baseline scores the runtime a user would declare —
    # the job's CPU-shape work — for BOTH pools, so it never discovers
    # the TPU frontier. Modeled as a constant predictor rather than
    # ``spec.duration`` because the virtual runner treats a declared
    # duration as ground truth (it would override the pool oracle).
    declared = sim(Placement(
        pools(), pricing=catalog, objective="cost",
        predictor=lambda spec, pool, res: spec.args["work"]))

    prior_pl = Placement(pools(), pricing=catalog, objective="cost")
    prior_pl.use_profiler(Profiler(engine=None, prior=_feedback_prior()))
    prior_only = sim(prior_pl)

    fb_prof = Profiler(engine=None, prior=_feedback_prior(),
                       recency_halflife=64)
    fb_pl = Placement(pools(), pricing=catalog, objective="cost")
    fb_pl.use_profiler(fb_prof)
    feedback = sim(fb_pl, prof=fb_prof)

    oracle_pl = Placement(pools(), pricing=catalog, objective="cost")
    oracle_pl.use_profiler(fit_hetero_profiler())
    oracle = sim(oracle_pl)

    # feedback must also CORRECT the prior's mis-calibrated constants,
    # not just preserve its routing: the refit per-pool TPU model's
    # estimate for a reference training job lands near the ground truth
    # the prior missed by ~37%
    ref_cfg = {"work": 2400.0, "chips": 8.0, "hbm_gb": 4.0}
    ref_truth = TPU_STARTUP + 2400.0 * 8.0 / (TPU_SPEED * 8.0)
    prior_pred = _feedback_prior().estimate("work", "tpu", ref_cfg)
    learned_pred = fb_prof.models["work@tpu"].predict(ref_cfg, clamp=True)
    learned_err = abs(learned_pred - ref_truth) / ref_truth
    prior_err = abs(prior_pred - ref_truth) / ref_truth

    out = {
        "fleet": {"n_jobs": n_jobs, "arrival_rate": FEEDBACK_RATE,
                  "cpu_nodes": CPU_NODES, "tpu_chips": TPU_CHIPS,
                  "prior_speed": PRIOR_SPEED,
                  "prior_startup_s": PRIOR_STARTUP},
        "declared": declared,
        "prior_only": prior_only,
        "prior_feedback": feedback,
        "oracle": oracle,
        "speedup_vs_declared":
            declared["makespan_s"] / feedback["makespan_s"],
        "oracle_gap": feedback["makespan_s"] / oracle["makespan_s"],
        "ref_train": {"true_runtime_s": ref_truth,
                      "prior_pred_s": prior_pred,
                      "learned_pred_s": learned_pred,
                      "prior_rel_err": prior_err,
                      "learned_rel_err": learned_err},
    }
    for name in ("declared", "prior_only", "prior_feedback", "oracle"):
        assert not out[name]["oversubscribed"], \
            f"feedback.{name} oversubscribed"
    assert out["speedup_vs_declared"] >= FEEDBACK_MIN_SPEEDUP, (
        f"cold-start prior+feedback only "
        f"{out['speedup_vs_declared']:.2f}x over declared durations "
        f"(gate: {FEEDBACK_MIN_SPEEDUP}x)")
    assert out["oracle_gap"] <= FEEDBACK_ORACLE_GAP, (
        f"prior+feedback makespan {out['oracle_gap']:.3f}x the oracle's "
        f"(gate: {FEEDBACK_ORACLE_GAP}x — not converging)")
    # the loop must not score a single silent 1.0s default: every rank
    # came from the prior or from a model refit off measured runtimes
    srcs = feedback["prediction_sources"]
    assert srcs.get("default", 0) == 0, f"silent defaults: {srcs}"
    assert srcs.get("prior", 0) > 0, f"prior never consulted: {srcs}"
    assert srcs.get("predictor", 0) > 0, f"feedback never served: {srcs}"
    assert learned_err < 0.15 and learned_err < prior_err, (
        f"feedback did not correct the prior: learned "
        f"{learned_pred:.0f}s vs true {ref_truth:.0f}s "
        f"(prior {prior_pred:.0f}s)")
    return out


# -- scenario 3: scheduler throughput at scale ---------------------------
def make_scale_fleet(seed: int = 0,
                     n_jobs: int = SCALE_JOBS) -> list[JobSpec]:
    """50k-job mixed fleet over 64 users and 3 accelerator pools: mostly
    small single-pool CPU profiling jobs, a GPU/TPU-flexible middle
    class, and a minority of big accelerator training jobs."""
    rng = np.random.default_rng(seed + 7)
    fleet = []
    for i in range(n_jobs):
        user = f"u{int(rng.integers(SCALE_USERS))}"
        r = rng.random()
        if r < 0.80:                 # CPU profiling sweep
            spec = JobSpec(
                name=f"prof-{i}", project="bench", user=user,
                duration=float(rng.uniform(5.0, 60.0)),
                resources={"vcpu": float(rng.choice([0.5, 1.0, 2.0])),
                           "mem_mb": float(rng.choice([512, 1024, 2048]))})
        elif r < 0.95:               # accelerator-flexible eval job
            spec = JobSpec(
                name=f"eval-{i}", project="bench", user=user,
                duration=float(rng.uniform(30.0, 120.0)),
                pool_resources={
                    "gpu": {"gpu": float(rng.choice([1, 2])),
                            "vram_gb": 8.0},
                    "tpu": {"chips": 8.0, "hbm_gb": 2.0}})
        else:                        # pinned training job
            pool = "tpu" if rng.random() < 0.5 else "gpu"
            res = {"tpu": {"chips": float(rng.choice([8, 16])),
                           "hbm_gb": 4.0},
                   "gpu": {"gpu": 8.0, "vram_gb": 40.0}}[pool]
            spec = JobSpec(
                name=f"train-{i}", project="bench", user=user,
                duration=float(rng.uniform(600.0, 1800.0)),
                pool=pool, pool_resources={pool: res})
        fleet.append(spec)
    return fleet


def _gpu_pool() -> AuditingCluster:
    return AuditingCluster(
        {"gpu": float(GPU_CHIPS), "vram_gb": 8.0 * GPU_CHIPS},
        {"gpu": 1.0, "vram_gb": 8.0}, name="gpu")


SCALE_RATE = 0.7    # ~75% steady-state CPU-pool load: heavy contention
                    # with a bounded backlog, so per-event dispatch cost
                    # (not queue blow-up) is what the scenario measures


def run_scale(n_jobs: int = SCALE_JOBS, seed: int = 0) -> dict:
    """Open-loop arrivals of the scale fleet onto a 3-pool deployment
    under fair+backfill — the dispatch hot path at fleet size. Asserts
    capacity is never oversubscribed on any pool."""
    fleet = make_scale_fleet(seed, n_jobs)
    arrivals = poisson_arrivals(fleet, rate=SCALE_RATE, seed=seed)
    catalog = {"cpu": CPU_PRICING, "tpu": TPU_BENCH_PRICING,
               "gpu": GPU_BENCH_PRICING}
    placement = Placement(
        {"cpu": _cpu_pool(CPU_NODES), "tpu": _tpu_pool(),
         "gpu": _gpu_pool()}, pricing=catalog)
    res = simulate(arrivals, placement=placement, pricing=catalog,
                   quota_k=32, policy="fair", backfill=True)
    res["fleet"] = {"n_jobs": n_jobs, "n_users": SCALE_USERS,
                    "pools": ["cpu", "gpu", "tpu"]}
    assert not res["oversubscribed"], "scale scenario oversubscribed"
    return res


# -- scenario 5: gang scheduling + topology-aware placement ---------------
def make_gang_arrivals(seed: int = 0, n_jobs: int = GANG_JOBS
                       ) -> list[tuple[float, JobSpec]]:
    """Open-loop mixed fleet: 1-pod sweep jobs plus close-topology 8-pod
    training gangs (4 GPUs per pod); ``args['work']`` is the job's
    runtime when its interconnect is not the bottleneck. Arrivals are
    Poisson at ~GANG_LOAD of the two pools' combined capacity, so jobs
    usually get their top-RANKED pool — what the scenario measures is
    the placement *choice*, not saturated work conservation (under which
    any two work-conserving schedules tie). The fleet ends with a
    *training wave* — the sweep campaign's winners scale up to gangs —
    so the makespan tail is gang runtime: a placement that spreads those
    gangs off-island pays the slowdown where it cannot hide."""
    rng = np.random.default_rng(seed + 11)

    def gang(i):
        return JobSpec(
            name=f"gang-{i}", project="bench",
            user=f"u{int(rng.integers(N_USERS))}",
            args={"work": float(rng.uniform(300.0, 900.0))},
            resources={"gpu": GANG_POD_GPUS},
            gang=GangSpec(n_pods=GANG_PODS, topology="close"))

    fleet = []
    for i in range(n_jobs):
        if rng.random() < GANG_FRACTION:
            fleet.append(gang(i))
        else:
            fleet.append(JobSpec(
                name=f"sweep-{i}", project="bench",
                user=f"u{int(rng.integers(N_USERS))}",
                args={"work": float(rng.uniform(120.0, 600.0))},
                resources={"gpu": 4.0}))
    # the fleet's slowdown-free GPU-seconds set the arrival span
    total = sum(s.args["work"] * s.resources["gpu"] * s.n_pods
                for s in fleet)
    span = total / (2 * 8.0 * GANG_NODES * GANG_LOAD)
    times = np.cumsum(rng.exponential(span / n_jobs, size=n_jobs))
    out = list(zip(times.tolist(), fleet))
    # the wave starts after the longest body job could drain, so both
    # configurations choose pools for it with comparable free capacity
    t_wave = float(times[-1]) + 960.0
    for k in range(GANG_WAVE):
        out.append((t_wave + 60.0 * k, gang(n_jobs + k)))
    return out


def gang_oracle(job) -> float:
    """Ground truth: a close-topology gang spread past its pool's
    interconnect island runs slower, in proportion to the off-island
    pod fraction (all-reduce over the slow links)."""
    work = job.spec.args["work"]
    gang = job.spec.gang
    close = GANG_CLOSE.get(job.pool)
    if gang is not None and gang.topology == "close" and \
            close is not None and close < gang.n_pods:
        frac = (gang.n_pods - close) / gang.n_pods
        return work * (1.0 + GANG_SPREAD_SLOWDOWN * frac)
    return work


def _gang_pools() -> dict[str, GangAuditingCluster]:
    shape = {"gpu": 8.0}
    return {name: GangAuditingCluster(
                {"gpu": 8.0 * GANG_NODES}, {"gpu": 1.0}, name=name,
                node_shape=shape, close_gang_pods=GANG_CLOSE[name])
            for name in ("pod", "island")}


def run_gang(n_jobs: int = GANG_JOBS, seed: int = 0,
             quota_k: int = 64) -> dict:
    """Gang-aware placement (transfer-cost model prices the interconnect
    spread) vs gang-oblivious (raw price only — it routes gangs to the
    cheap 'island' pool, where the oracle slows them down) on identical
    fleets. Hard gates: gang-aware wins makespan, and no gang ever
    partially holds capacity in either configuration (audited at every
    reserve, success or failure)."""
    arrivals = make_gang_arrivals(seed, n_jobs)
    catalog = {"pod": GANG_POD_PRICING, "island": GANG_ISLAND_PRICING}

    def run_one(transfer):
        pools = _gang_pools()
        placement = Placement(pools, pricing=catalog, objective="cost",
                              transfer_costs=transfer)
        res = simulate(arrivals, placement=placement, pricing=catalog,
                       oracle=gang_oracle, quota_k=quota_k)
        res["gang_reserves"] = sum(cl.gang_reserves
                                   for cl in pools.values())
        res["partial_gang_holds"] = sum(cl.partial_gang_holds
                                        for cl in pools.values())
        res["reserve_violations"] = sum(cl.reserve_violations
                                        for cl in pools.values())
        return res

    aware = run_one(TransferCostModel(
        interconnect_weight=GANG_INTERCONNECT_W))
    oblivious = run_one(None)
    out = {
        "fleet": {"n_jobs": n_jobs, "n_users": N_USERS,
                  "gang_pods": GANG_PODS,
                  "nodes_per_pool": GANG_NODES,
                  "close_gang_pods": dict(GANG_CLOSE),
                  "spread_slowdown": GANG_SPREAD_SLOWDOWN},
        "gang_aware": aware,
        "gang_oblivious": oblivious,
        "makespan_speedup":
            oblivious["makespan_s"] / aware["makespan_s"],
    }
    for name, r in (("aware", aware), ("oblivious", oblivious)):
        assert r["gang_reserves"] > 0, f"gang.{name}: gangs never reserved"
        assert r["partial_gang_holds"] == 0, \
            f"gang.{name}: a gang partially held capacity"
        assert r["reserve_violations"] == 0 and not r["oversubscribed"], \
            f"gang.{name}: oversubscribed"
    assert aware["makespan_s"] < oblivious["makespan_s"], \
        "gang-aware placement did not beat gang-oblivious on makespan"
    return out


# -- scenario 6: thundering herd vs fair share ----------------------------
def make_herd_arrivals(seed: int = 0, n_herd: int = HERD_JOBS,
                       n_others: int = 0) -> list[tuple[float, JobSpec]]:
    """One user ``map()``-fans ``n_herd`` short jobs at t=0; ``n_others``
    background jobs from HERD_OTHERS other users trickle in uniformly
    while the burst drains."""
    rng = np.random.default_rng(seed + 123)
    arrivals = [(0.0, JobSpec(
        name=f"herd-{i}", project="bench", user="u_herd",
        duration=float(rng.uniform(5.0, 20.0)),
        resources={"vcpu": 1.0, "mem_mb": 512.0}))
        for i in range(n_herd)]
    # approximate burst drain time on the NODES-node cluster: the window
    # background arrivals must ride out without starving
    span = n_herd * 12.5 / (NODES * 8.0)
    for i in range(n_others):
        user = f"u{int(rng.integers(HERD_OTHERS))}"
        arrivals.append((float(rng.uniform(0.0, span)), JobSpec(
            name=f"bg-{i}", project="bench", user=user,
            duration=float(rng.uniform(10.0, 60.0)),
            resources={"vcpu": 1.0, "mem_mb": 1024.0})))
    arrivals.sort(key=lambda p: p[0])
    return arrivals


def run_herd(n_herd: int = HERD_JOBS, seed: int = 0) -> dict:
    """FIFO vs fair-share under one user's 10k-job burst. The gate: fair
    share keeps the OTHER users' p95 queue wait under HERD_P95_BOUND
    seconds (and far below FIFO's, which makes them ride out the whole
    burst) — one user fanning a sweep cannot monopolize the cluster."""
    arrivals = make_herd_arrivals(seed, n_herd, max(200, n_herd // 5))

    def run_one(policy: str, backfill: bool) -> dict:
        cluster = AuditingCluster(
            {n: max(d.values) * NODES for n, d in CPU_PRICING.dims.items()},
            {n: d.minimum for n, d in CPU_PRICING.dims.items()})
        waits: dict[str, list[float]] = {}
        res = simulate(arrivals, cluster=cluster, pricing=CPU_PRICING,
                       policy=policy, backfill=backfill, user_waits=waits)
        others = [w for u, ws in waits.items() if u != "u_herd" for w in ws]
        res["others_wait_p95_s"] = \
            float(np.percentile(others, 95)) if others else 0.0
        res["herd_wait_p95_s"] = \
            float(np.percentile(waits.get("u_herd", [0.0]), 95))
        return res

    fifo = run_one("fifo", backfill=False)
    fair = run_one("fair", backfill=True)
    out = {
        "fleet": {"n_herd": n_herd, "n_other_users": HERD_OTHERS,
                  "nodes": NODES},
        "fifo": fifo,
        "fair_backfill": fair,
        "others_p95_cut":
            1.0 - fair["others_wait_p95_s"] /
            max(fifo["others_wait_p95_s"], 1e-9),
    }
    assert not fifo["oversubscribed"] and not fair["oversubscribed"]
    assert fair["others_wait_p95_s"] <= HERD_P95_BOUND, \
        (f"herd: fair-share others' p95 wait "
         f"{fair['others_wait_p95_s']:.0f}s exceeds {HERD_P95_BOUND:.0f}s")
    assert fair["others_wait_p95_s"] < 0.25 * fifo["others_wait_p95_s"], \
        "herd: fair-share did not materially beat FIFO for other users"
    return out


# -- scenario 4: elastic spot pools + checkpoint-aware preemption --------
def make_elastic_fleet(seed: int = 0,
                       n_jobs: int = ELASTIC_JOBS) -> list[JobSpec]:
    """Two-class fleet: 85% whole-node batch training jobs (priority 0,
    5–15 min) that pack the pools solid, and 15% small high-priority
    interactive jobs (priority 10, 20–90 s) that starve behind them
    unless the scheduler preempts."""
    rng = np.random.default_rng(seed + 42)
    fleet = []
    for i in range(n_jobs):
        user = f"u{int(rng.integers(N_USERS))}"
        if rng.random() < 0.15:
            fleet.append(JobSpec(
                name=f"int-{i}", project="bench", user=user, priority=10,
                duration=float(rng.uniform(20.0, 90.0)),
                resources={"vcpu": 1.0, "mem_mb": 1024.0}))
        else:
            fleet.append(JobSpec(
                name=f"batch-{i}", project="bench", user=user,
                duration=float(rng.uniform(300.0, 900.0)),
                resources={"vcpu": 8.0, "mem_mb": 8192.0}))
    return fleet


def _node_shape() -> dict[str, float]:
    return {n: float(max(d.values)) for n, d in CPU_PRICING.dims.items()}


def _elastic_pool(nodes: int, name: str, *, spot: bool = False,
                  reclaim_rate: float = 0.0) -> AuditingCluster:
    return AuditingCluster(
        {n: amt * nodes for n, amt in _node_shape().items()},
        {n: d.minimum for n, d in CPU_PRICING.dims.items()}, name=name,
        spot=spot, reclaim_rate=reclaim_rate)


def _wait_stats(registry, submitted, starts):
    """Per-class queue-wait stats: interactive p95 is the starvation
    signal (a preempted job's wait is its first-launch wait)."""
    int_w, batch_w = [], []
    for jid, t_sub in submitted.items():
        if jid not in starts:
            continue
        wait = starts[jid] - t_sub
        name = registry.get(jid).spec.name
        (int_w if name.startswith("int-") else batch_w).append(wait)
    return {
        "interactive_wait_p95_s":
            float(np.percentile(int_w, 95)) if int_w else 0.0,
        "batch_wait_p95_s":
            float(np.percentile(batch_w, 95)) if batch_w else 0.0,
    }


def simulate_elastic(arrivals, *, quota_k: int = 64,
                     seed: int = 0) -> dict:
    """The elastic configuration: an on-demand pool the provisioning
    controller grows/shrinks in [1, ELASTIC_MAX_NODES] nodes, plus a
    spot pool at 40% of the on-demand price whose capacity the cloud
    *takes away* at exponential intervals — a reclamation shrinks the
    pool by one node for SPOT_OUTAGE seconds (the node really is gone:
    displaced jobs cannot relaunch onto it), draining the displaced
    reservations through the checkpoint-aware preemption path; the same
    preemption policy un-starves high-priority heads. Spot provisioned
    cost integrates the *live* node count, so outages are not billed.
    The virtual-clock loop interleaves arrivals, completions,
    reclamations, node restores and controller rounds in timestamp
    order."""
    registry = JobRegistry()
    bus = EventBus()
    node_shape = _node_shape()
    spot_pr = spot_pricing(CPU_PRICING, SPOT_DISCOUNT, family="spot")
    catalog = {"ondemand": CPU_PRICING, "spot": spot_pr}
    runner = VirtualRunner(registry, bus, pricing=catalog,
                           checkpoint_interval=ELASTIC_CKPT)
    ond = _elastic_pool(1, "ondemand")      # the controller grows it
    spot = _elastic_pool(SPOT_NODES, "spot", spot=True,
                         reclaim_rate=1.0 / ELASTIC_RECLAIM_MEAN)
    placement = Placement({"ondemand": ond, "spot": spot},
                          pricing=catalog, objective="cost")
    sched = Scheduler(registry, runner, bus, quota_k=quota_k,
                      placement=placement, policy="fair", backfill=True,
                      preemption=True,
                      starvation_threshold=ELASTIC_STARVE,
                      snapshot_interval=3600.0)
    ctl = ElasticController(sched, {"ondemand": PoolPolicy(
        node_shape=node_shape, min_nodes=1, max_nodes=ELASTIC_MAX_NODES,
        grow_at=0.85, shrink_at=0.25, cooldown_s=ELASTIC_CTL_EVERY)})
    rng = np.random.default_rng(seed + 777)
    next_reclaim = float(rng.exponential(ELASTIC_RECLAIM_MEAN))
    next_ctl = ELASTIC_CTL_EVERY
    spot_nodes = SPOT_NODES
    restores: list[float] = []      # pending node-return times
    # (t, nodes) change-points for the spot node-hour integral
    spot_segments: list[tuple[float, int]] = [(0.0, spot_nodes)]
    reclaim_events = 0

    def set_spot_nodes(n: int) -> None:
        nonlocal spot_nodes
        spot_nodes = n
        sched.resize_pool(
            "spot", {d: amt * n for d, amt in node_shape.items()})
        spot_segments.append((runner.now, n))

    starts: dict[str, float] = {}
    orig_launch = runner.launch

    def launch(job):
        starts.setdefault(job.job_id, runner.now)   # first launch = wait
        orig_launch(job)
    runner.launch = launch

    submitted: dict[str, float] = {}
    queued = lambda: sum(sched._qlen.values())
    t0 = time.perf_counter()
    i = 0
    while i < len(arrivals) or runner.pending() > 0 or queued() > 0:
        t_arr = arrivals[i][0] if i < len(arrivals) else float("inf")
        t_res = restores[0] if restores else float("inf")
        t_ext = min(t_arr, next_reclaim, next_ctl, t_res)
        while True:     # drain completions due before the next event
            nc = runner.next_completion()
            if nc is None or nc > t_ext:
                break
            runner.step()
        runner.advance_to(t_ext)
        if t_arr <= t_ext and i < len(arrivals):
            job = registry.submit(copy.copy(arrivals[i][1]))
            submitted[job.job_id] = t_arr
            sched.submit(job)
            i += 1
        if next_reclaim <= t_ext:
            # the cloud takes a node back for SPOT_OUTAGE seconds: the
            # capacity really shrinks, and the displaced reservations
            # drain through the checkpoint-aware preemption path —
            # victims cannot simply relaunch onto the reclaimed node
            if spot_nodes > 0:
                reclaim_events += 1
                set_spot_nodes(spot_nodes - 1)
                restores.append(runner.now + SPOT_OUTAGE)
                restores.sort()
            next_reclaim = runner.now + \
                float(rng.exponential(ELASTIC_RECLAIM_MEAN))
        while restores and restores[0] <= t_ext:
            restores.pop(0)
            set_spot_nodes(min(SPOT_NODES, spot_nodes + 1))
        if next_ctl <= t_ext:
            ctl.step(runner.now)
            next_ctl = runner.now + ELASTIC_CTL_EVERY
    wall = time.perf_counter() - t0

    jobs = registry.all_jobs()
    finished = sum(1 for j in jobs if j.state == JobState.FINISHED)
    assert finished == len(arrivals), f"{finished}/{len(arrivals)} finished"
    # capacity invariant on elastic pools: no reserve ever oversubscribed
    # the capacity in force at that moment (post-shrink over-commit is
    # legitimate and drains through preemption)
    assert not any(getattr(cl, "reserve_violations", 0)
                   for cl in sched.pools.values())
    makespan = runner.now
    node_rate = CPU_PRICING.hourly_rate(node_shape)
    spot_rate = spot_pr.hourly_rate(node_shape)
    # spot node-hours integrate the live node count across outages
    spot_hours = 0.0
    for k, (t_a, n_a) in enumerate(spot_segments):
        t_b = spot_segments[k + 1][0] if k + 1 < len(spot_segments) \
            else makespan
        spot_hours += n_a * max(0.0, t_b - t_a)
    spot_hours /= 3600.0
    provisioned = ctl.provisioned_cost(makespan,
                                       {"ondemand": node_rate}) + \
        spot_hours * spot_rate
    res = {
        "n_jobs": len(arrivals),
        "makespan_s": makespan,
        "mean_queue_wait_s": sched.mean_queue_wait(),
        "total_cost": sum(j.cost or 0.0 for j in jobs),
        "provisioned_cost": provisioned,
        "ondemand_node_hours": ctl.node_hours(makespan)["ondemand"],
        "spot_node_hours": spot_hours,
        "preempted": sched.stats["preempted"],
        "spot_reclaims": reclaim_events,
        "reclaim_drained": sched.stats["drained"],
        "scale_ops": len(ctl.decisions),
        "lost_work_s": runner.preempt_stats["lost_work_s"],
        "max_lost_work_s": runner.preempt_stats["max_lost_s"],
        "resumed_work_s": runner.preempt_stats["resumed_s"],
        "placed_by_pool": dict(sched.stats["placed_by_pool"]),
        "wall_s": wall,
    }
    res.update(_wait_stats(registry, submitted, starts))
    return res


def run_elastic(n_jobs: int = ELASTIC_JOBS, seed: int = 0,
                quota_k: int = 64) -> dict:
    """Static on-demand vs elastic(spot + preemption) on identical
    fleets. The acceptance gate: the elastic configuration must win on
    billed AND provisioned cost at equal-or-better makespan, preempted
    work must resume from checkpoints (lost work bounded by the
    checkpoint interval), and high-priority jobs must stop starving."""
    fleet = make_elastic_fleet(seed, n_jobs)
    arrivals = poisson_arrivals(fleet, ELASTIC_RATE, seed)
    node_shape = _node_shape()
    node_rate = CPU_PRICING.hourly_rate(node_shape)
    catalog = {"ondemand": CPU_PRICING}

    # static: the on-demand pool at max size, no elasticity, no spot,
    # no preemption — the pre-PR engine on price-equivalent hardware
    static = simulate(
        arrivals, pricing=catalog, quota_k=quota_k,
        placement=Placement(
            {"ondemand": _elastic_pool(ELASTIC_MAX_NODES, "ondemand")},
            pricing=catalog))
    static["provisioned_cost"] = \
        ELASTIC_MAX_NODES * node_rate * static["makespan_s"] / 3600.0

    elastic = simulate_elastic(arrivals, quota_k=quota_k, seed=seed)

    out = {
        "fleet": {"n_jobs": n_jobs, "n_users": N_USERS,
                  "ondemand_nodes_static": ELASTIC_MAX_NODES,
                  "ondemand_nodes_elastic":
                      f"1..{ELASTIC_MAX_NODES} (controller)",
                  "spot_nodes": SPOT_NODES,
                  "spot_discount": SPOT_DISCOUNT,
                  "checkpoint_interval_s": ELASTIC_CKPT,
                  "reclaim_mean_s": ELASTIC_RECLAIM_MEAN,
                  "starvation_threshold_s": ELASTIC_STARVE},
        "static_ondemand": static,
        "elastic_spot": elastic,
        "cost_saving_billed":
            1.0 - elastic["total_cost"] / static["total_cost"],
        "cost_saving_provisioned":
            1.0 - elastic["provisioned_cost"] / static["provisioned_cost"],
        "makespan_ratio": elastic["makespan_s"] / static["makespan_s"],
    }
    # the acceptance gate (ISSUE 5): cheaper on both cost axes at
    # equal-or-better makespan, checkpoint-bounded lost work, real resumes
    assert elastic["makespan_s"] <= static["makespan_s"] + 1e-6, \
        "elastic makespan regressed"
    assert elastic["total_cost"] < static["total_cost"], \
        "no billed-cost saving"
    assert elastic["provisioned_cost"] < static["provisioned_cost"], \
        "no provisioned-cost saving"
    assert elastic["preempted"] > 0, "preemption never exercised"
    assert elastic["resumed_work_s"] > 0, "no checkpoint resume happened"
    assert elastic["max_lost_work_s"] <= ELASTIC_CKPT + 1e-6, \
        "lost work exceeds the checkpoint interval"
    return out


# -- scenario 7: kill -9 crash recovery ----------------------------------
def run_recovery(n_jobs: int = RECOVERY_JOBS, seed: int = RECOVERY_SEED,
                 kill_at_frac: float = RECOVERY_KILL_FRAC) -> dict:
    """The durable control plane's exit criterion, measured: run the
    crash drill's seeded fleet in a subprocess, SIGKILL it once its
    heartbeat shows ~``kill_at_frac`` of the fleet completed, recover
    in-process and drain the rest. Hard gates: the post-recovery final
    states equal an uninterrupted golden run's, every submitted job
    reaches a terminal state exactly once, and no capacity release ever
    underflowed."""
    from repro.core.engine.durable import drill

    with tempfile.TemporaryDirectory(prefix="acai-recovery-") as tmp:
        golden = drill.run_fresh(Path(tmp) / "golden", n_jobs, seed)

        victim = Path(tmp) / "victim"
        victim.mkdir()
        src = str(Path(__file__).resolve().parents[1] / "src")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [src] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.core.engine.durable.drill",
             "--dir", str(victim), "--n-jobs", str(n_jobs),
             "--seed", str(seed)],
            stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL, env=env)
        # the drill heartbeats completion counts every 25 jobs: kill at
        # the first beat past the target, i.e. genuinely mid-fleet
        kill_target = max(25, int(n_jobs * kill_at_frac))
        heartbeat = victim / "progress"
        deadline = time.monotonic() + 600.0
        killed_at = None
        while time.monotonic() < deadline:
            if proc.poll() is not None:
                raise AssertionError(
                    "recovery: the drill completed before the kill "
                    "threshold — raise n_jobs or lower kill_at_frac")
            try:
                done = int(heartbeat.read_text() or 0)
            except (OSError, ValueError):
                done = 0
            if done >= kill_target:
                killed_at = done
                break
            time.sleep(0.02)
        assert killed_at is not None, "recovery: drill never heartbeat"
        proc.send_signal(signal.SIGKILL)
        proc.wait()

        t0 = time.perf_counter()
        out = drill.resume(victim, n_jobs, seed)
        resume_wall = time.perf_counter() - t0

    final, report = out["final"], out["report"]
    lost = sorted(set(golden) - set(final))
    mismatched = {j: (golden[j], final[j]) for j in golden
                  if j in final and final[j] != golden[j]}
    res = {
        "n_jobs": n_jobs,
        "killed_at_completions": killed_at,
        "recovery_wall_s": report["wall_s"],
        "resume_total_wall_s": resume_wall,
        "events_replayed": report["events_replayed"],
        "terminal_at_crash": report["terminal"],
        "requeued": report["requeued"],
        "resumed_from_checkpoint": report["resumed"],
        "completed_after_recovery": out["completed_after_recovery"],
        "lost_jobs": len(lost),
        "mismatched_states": len(mismatched),
        "duplicate_terminals": len(out["duplicate_terminals"]),
        "release_underflow": out["release_underflow"],
        "states_match_golden": not lost and not mismatched
        and len(final) == len(golden),
    }
    assert res["states_match_golden"], \
        (f"recovery: post-recovery states diverge from golden "
         f"(lost={lost[:5]}, mismatched={dict(list(mismatched.items())[:5])})")
    assert res["duplicate_terminals"] == 0, \
        f"recovery: {out['duplicate_terminals']} jobs settled twice"
    assert res["release_underflow"] == 0, \
        "recovery: capacity books unbalanced (release underflow)"
    assert report["requeued"] > 0, "recovery: the kill landed too late " \
        "to requeue anything — not a mid-fleet crash"
    return res


# -- chaos scenario: the fault-tolerance layer, measured ------------------
def make_chaos_params(seed: int, n_jobs: int) -> list[dict]:
    """One seeded draw of job parameters, shared by every chaos
    configuration — the A/B difference must be the retry policy, never
    the fleet."""
    rng = np.random.default_rng(seed + 77)
    params = []
    for i in range(n_jobs):
        vcpu = float(rng.choice([1.0, 2.0, 4.0]))
        params.append({
            "name": f"work-{i}", "user": f"u{int(rng.integers(4))}",
            "duration": float(rng.uniform(30.0, 300.0)), "vcpu": vcpu,
            # 1 in 10 carries a generous deadline: enforcement runs, but
            # only a badly-starved job actually gets killed by it
            "deadline": bool(rng.random() < 0.1)})
    for i in range(CHAOS_DOOMED):
        params.append({"name": f"doomed-{i}", "user": "crashloop",
                       "duration": 60.0, "vcpu": 1.0, "deadline": False})
    rng.shuffle(params)
    return params


def make_chaos_fleet(params: list[dict], *, retry: bool,
                     features: bool = True) -> list[JobSpec]:
    """``retry`` toggles the policy under test; ``features=False`` strips
    every fault-tolerance knob (the golden-trace configuration)."""
    specs = []
    for p in params:
        kw = {}
        if features:
            kw["timeout_s"] = 2.5 * p["duration"]
            if p["deadline"]:
                kw["deadline"] = 6.0 * p["duration"] + 1800.0
        if retry:
            kw["retry"] = RetryPolicy(
                max_retries=CHAOS_MAX_RETRIES, backoff_base=5.0,
                backoff_cap=60.0,
                retry_on="any" if p["name"].startswith("doomed")
                else "transient")
        specs.append(JobSpec(
            name=p["name"], project="bench", user=p["user"],
            duration=p["duration"],
            resources={"vcpu": p["vcpu"], "mem_mb": 512.0 * p["vcpu"]},
            **kw))
    return specs


def simulate_chaos(arrivals, *, plan: FaultPlan | None,
                   quota_k: int = 64) -> dict:
    """Drive one fleet through the fault-tolerance event loop: advance
    the virtual clock to ``min(next completion, next scheduler timer,
    next injected fault)``, apply, tick. Doomed jobs crash fatally at
    every launch (the harness's crash loop); everything else fails only
    when the injector says so."""
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus, pricing=CPU_PRICING)
    cluster = AuditingCluster(
        {n: v * CHAOS_NODES for n, v in CHAOS_NODE_SHAPE.items()},
        {"vcpu": 1.0, "mem_mb": 512.0}, name="chaos",
        node_shape=dict(CHAOS_NODE_SHAPE))
    sched = Scheduler(registry, runner, bus, quota_k=quota_k,
                      cluster=cluster, policy="fair", backfill=True,
                      quarantine_threshold=CHAOS_QUARANTINE_K,
                      snapshot_interval=3600.0)
    # terminal-event handler order matters: the scheduler (already
    # subscribed) decides retry-or-not before the monitor caches a status
    monitor = JobMonitor(bus, registry=registry)
    inj = FaultInjector(plan, sched, runner) if plan is not None else None

    orig_launch = runner.launch

    def launch(job):
        orig_launch(job)
        if job.spec.name.startswith("doomed"):
            # fatal on every incarnation: the crash loop quarantine is
            # built to cut off (backoff holds the rebirth, so this does
            # not recurse inside the dispatch that launched it)
            runner.fail_running(job, error="crash loop: segfault on "
                                "start", transient=False)
    runner.launch = launch

    def drain(until=None):
        guard = 0
        while True:
            guard += 1
            assert guard < 2_000_000, "chaos event loop livelocked"
            if until is None and all(j.state in TERMINAL_STATES
                                     for j in registry.all_jobs()):
                break
            cands = [runner.next_completion(), sched.next_timer()]
            if inj is not None:
                cands.append(inj.next_event())
            live = [t for t in cands if t is not None]
            if not live:
                break
            t = min(live)
            if until is not None and t > until:
                break
            nc = runner.next_completion()
            if nc is not None and nc <= t + 1e-9:
                runner.step()
            else:
                runner.advance_to(t)
            if inj is not None:
                inj.advance_to(runner.now)
            sched.tick()

    t0 = time.perf_counter()
    for t, spec in arrivals:
        drain(until=t)
        runner.advance_to(t)
        if inj is not None:
            inj.advance_to(runner.now)
        sched.tick()
        sched.submit(registry.submit(copy.copy(spec)))
    drain()
    wall = time.perf_counter() - t0

    jobs = registry.all_jobs()
    non_terminal = sum(1 for j in jobs if j.state not in TERMINAL_STATES)
    finished_work = sum(j.spec.duration or 0.0 for j in jobs
                        if j.state == JobState.FINISHED)
    makespan = runner.now
    states: dict[str, int] = {}
    for j in jobs:
        states[j.state.value] = states.get(j.state.value, 0) + 1
    return {
        "n_jobs": len(arrivals),
        "makespan_s": makespan,
        "goodput_work_s_per_s": finished_work / max(makespan, 1e-9),
        "finished": states.get("FINISHED", 0),
        "failed": states.get("FAILED", 0),
        "killed": states.get("KILLED", 0),
        "quarantined": states.get("QUARANTINED", 0),
        "non_terminal": non_terminal,
        "retried": sched.stats.get("retried", 0),
        "timeouts": sched.stats.get("timeouts", 0),
        "deadline_kills": sched.stats.get("deadline_kills", 0),
        "node_failures": sched.stats.get("node_failures", 0),
        "retry_wasted_s": sched.stats.get("retry_wasted_s", 0.0),
        "injected": [e for e in (inj.events if inj else [])
                     if "skipped" not in e],
        "oversubscribed": cluster.oversubscribed,
        "max_retries_seen": max((j.retries for j in jobs), default=0),
        "doomed_retries": {j.job_id: j.retries for j in jobs
                           if j.spec.name.startswith("doomed")},
        "state_trace": sorted((j.spec.name, j.state.value,
                               round(j.runtime or 0.0, 9))
                              for j in jobs),
        "wall_s": wall,
    }


def run_chaos(n_jobs: int = CHAOS_JOBS, seed: int = CHAOS_SEED) -> dict:
    """The fault-tolerance exit criterion, measured. Two runs over one
    fleet shape and one seeded fault schedule — retry budgets +
    quarantine ON vs OFF — plus a golden pair proving the chaos
    machinery is a bit-identical no-op when disabled. Hard gates:

    - goodput (finished declared work per makespan second) with the
      layer ON is >= ``CHAOS_GOODPUT_GATE``x the no-retry run's;
    - every job reaches a terminal state in both runs (nothing sticks);
    - waste is bounded by the budget: no job exceeds its max_retries,
      and every crash-looping job is quarantined before burning its full
      budget;
    - with features off, an attached-but-inert injector changes nothing:
      final (state, runtime) per job and makespan are bit-identical."""
    params = make_chaos_params(seed, n_jobs)
    plan = FaultPlan(seed=seed, **CHAOS_PLAN)
    base_arrivals = poisson_arrivals(
        make_chaos_fleet(params, retry=False), CHAOS_RATE, seed)
    ft_arrivals = poisson_arrivals(
        make_chaos_fleet(params, retry=True), CHAOS_RATE, seed)

    base = simulate_chaos(base_arrivals, plan=plan)
    ft = simulate_chaos(ft_arrivals, plan=plan)

    # golden pair: zero fault-tolerance features, injector attached with
    # an all-disabled plan vs not attached at all
    vanilla = poisson_arrivals(
        make_chaos_fleet(params, retry=False, features=False),
        CHAOS_RATE, seed)
    golden = simulate_chaos(vanilla, plan=None)
    inert = simulate_chaos(vanilla, plan=FaultPlan(seed=seed))
    golden_match = (golden["state_trace"] == inert["state_trace"]
                    and golden["makespan_s"] == inert["makespan_s"])

    goodput_ratio = ft["goodput_work_s_per_s"] / \
        max(base["goodput_work_s_per_s"], 1e-9)
    res = {
        "fleet": {"n_jobs": len(base_arrivals), "nodes": CHAOS_NODES,
                  "arrival_rate": CHAOS_RATE, "doomed": CHAOS_DOOMED,
                  "plan": dict(CHAOS_PLAN, seed=seed)},
        "no_retry": base,
        "retry": ft,
        "goodput_ratio": goodput_ratio,
        "golden_match": golden_match,
        "injected_faults": len(ft["injected"]),
    }
    for tag, r in (("no_retry", base), ("retry", ft)):
        assert r["non_terminal"] == 0, \
            f"chaos[{tag}]: {r['non_terminal']} jobs stuck non-terminal"
        assert not r["oversubscribed"], f"chaos[{tag}]: oversubscribed"
    assert ft["injected"] and base["injected"], \
        "chaos: the fault plan never fired — raise the rates"
    assert goodput_ratio >= CHAOS_GOODPUT_GATE, \
        (f"chaos: retry goodput only {goodput_ratio:.2f}x no-retry "
         f"(gate {CHAOS_GOODPUT_GATE}x)")
    assert ft["quarantined"] == CHAOS_DOOMED, \
        (f"chaos: {ft['quarantined']} quarantined, expected every one of "
         f"the {CHAOS_DOOMED} crash-looping jobs")
    assert ft["max_retries_seen"] <= CHAOS_MAX_RETRIES, \
        "chaos: a job exceeded its retry budget"
    assert all(r <= CHAOS_QUARANTINE_K - 1
               for r in ft["doomed_retries"].values()), \
        (f"chaos: a crash loop burned past the quarantine threshold: "
         f"{ft['doomed_retries']}")
    assert golden_match, \
        "chaos: inert injector perturbed the golden trace"
    for r in (base, ft):        # audit-log bulk stays out of the JSON
        r["injected"] = len(r["injected"])
        del r["state_trace"]
    return res


# -- smoke regression gate -----------------------------------------------
def check_throughput_regression(measured: dict, path: str,
                                threshold: float = 0.7) -> list[str]:
    """Compare measured ``sched_events_per_s`` per policy against the
    committed BENCH_scheduler.json; a drop below ``threshold`` x the
    committed number is a regression (the CI --smoke gate fails on it)."""
    try:
        with open(path) as f:
            committed = json.load(f)
    except (OSError, json.JSONDecodeError):
        return []
    failures = []
    for name in ("fifo", "fair_backfill"):
        base = committed.get(name, {}).get("sched_events_per_s")
        got = measured.get(name, {}).get("sched_events_per_s")
        if base and got and got < threshold * base:
            failures.append(
                f"{name}: {got:.0f}/s < {threshold:.0%} of committed "
                f"{base:.0f}/s")
    return failures


# -- entry points -------------------------------------------------------
def run(n_jobs: int = N_JOBS, seed: int = 0,
        hetero_jobs: int = HETERO_JOBS, trace: str | None = None,
        scale_jobs: int = SCALE_JOBS, policy_repeats: int = 3,
        elastic_jobs: int = ELASTIC_JOBS, gang_jobs: int = GANG_JOBS,
        herd_jobs: int = HERD_JOBS,
        recovery_jobs: int = RECOVERY_JOBS,
        feedback_jobs: int = FEEDBACK_JOBS,
        chaos_jobs: int = CHAOS_JOBS) -> dict:
    arrivals = trace_arrivals(trace) if trace else \
        poisson_arrivals(make_fleet(seed, n_jobs), ARRIVAL_RATE, seed)
    fifo = run_policy(arrivals, "fifo", backfill=False,
                      repeats=policy_repeats)
    fair = run_policy(arrivals, "fair", backfill=True,
                      repeats=policy_repeats)
    out = {
        "fleet": {"n_jobs": len(arrivals), "n_users": N_USERS,
                  "nodes": NODES, "arrival_rate": ARRIVAL_RATE,
                  "arrivals": "trace" if trace else "poisson"},
        "fifo": fifo,
        "fair_backfill": fair,
        "makespan_speedup": fifo["makespan_s"] / fair["makespan_s"],
        "queue_wait_reduction":
            1.0 - fair["mean_queue_wait_s"] / fifo["mean_queue_wait_s"],
        "hetero": run_hetero(hetero_jobs, seed),
    }
    if feedback_jobs:
        out["feedback"] = run_feedback(feedback_jobs, seed)
    if gang_jobs:
        out["gang"] = run_gang(gang_jobs, seed)
    if herd_jobs:
        out["herd"] = run_herd(herd_jobs, seed)
    if elastic_jobs:
        out["elastic"] = run_elastic(elastic_jobs, seed)
    if chaos_jobs:
        out["chaos"] = run_chaos(chaos_jobs)
    if recovery_jobs:
        out["recovery"] = run_recovery(recovery_jobs)
    if scale_jobs:
        out["scale"] = run_scale(scale_jobs, seed)
    assert not fifo["oversubscribed"] and not fair["oversubscribed"]
    return out


def report(res: dict, write: bool = True) -> None:
    """Print the CSV contract lines and write BENCH_scheduler.json —
    shared between standalone runs and benchmarks/run.py."""
    for name in ("fifo", "fair_backfill"):
        r = res[name]
        print(f"scheduler.{name},{r['wall_s'] * 1e6:.0f},"
              f"makespan={r['makespan_s']:.0f}s"
              f"_wait={r['mean_queue_wait_s']:.0f}s"
              f"_slowdown_p50={r['slowdown_p50']:.1f}"
              f"_p95={r['slowdown_p95']:.1f}"
              f"_p99={r['slowdown_p99']:.1f}"
              f"_backfilled={r['backfilled']}")
    print(f"scheduler.speedup,0,makespan_x={res['makespan_speedup']:.3f}"
          f"_wait_cut={res['queue_wait_reduction'] * 100:.1f}%")
    h = res["hetero"]
    for name in ("single_pool", "random_pool", "profiler_placed"):
        r = h[name]
        pools = ",".join(f"{p}:{c}" for p, c in
                         sorted(r["placed_by_pool"].items()))
        print(f"scheduler.hetero.{name},{r['wall_s'] * 1e6:.0f},"
              f"makespan={r['makespan_s']:.0f}s"
              f"_cost=${r['total_cost']:.2f}_pools={pools or '-'}")
    print(f"scheduler.hetero.placement,0,"
          f"speedup_vs_single={h['makespan_speedup_vs_single']:.2f}x"
          f"_vs_random={h['makespan_speedup_vs_random']:.2f}x"
          f"_cost_cut_vs_single={h['cost_saving_vs_single'] * 100:.1f}%"
          f"_vs_random={h['cost_saving_vs_random'] * 100:.1f}%")
    print(f"scheduler.throughput,0,"
          f"fifo={res['fifo']['sched_events_per_s']:.0f}/s"
          f"_fair={res['fair_backfill']['sched_events_per_s']:.0f}/s")
    if "feedback" in res:
        fb = res["feedback"]
        for name in ("declared", "prior_only", "prior_feedback", "oracle"):
            r = fb[name]
            pools = ",".join(f"{p}:{c}" for p, c in
                             sorted(r["placed_by_pool"].items()))
            srcs = ",".join(f"{k}:{v}" for k, v in
                            sorted(r["prediction_sources"].items()) if v)
            print(f"scheduler.feedback.{name},{r['wall_s'] * 1e6:.0f},"
                  f"makespan={r['makespan_s']:.0f}s"
                  f"_pools={pools or '-'}_sources={srcs or '-'}")
        rt = fb["ref_train"]
        print(f"scheduler.feedback.convergence,0,"
              f"speedup_vs_declared={fb['speedup_vs_declared']:.2f}x"
              f"_oracle_gap={fb['oracle_gap']:.3f}x"
              f"_ref_pred={rt['learned_pred_s']:.0f}s"
              f"_prior={rt['prior_pred_s']:.0f}s"
              f"_true={rt['true_runtime_s']:.0f}s")
    if "gang" in res:
        g = res["gang"]
        for name in ("gang_aware", "gang_oblivious"):
            r = g[name]
            pools = ",".join(f"{p}:{c}" for p, c in
                             sorted(r["placed_by_pool"].items()))
            print(f"scheduler.{name},{r['wall_s'] * 1e6:.0f},"
                  f"makespan={r['makespan_s']:.0f}s"
                  f"_gangs={r['gang_reserves']}"
                  f"_partial_holds={r['partial_gang_holds']}"
                  f"_pools={pools}")
        print(f"scheduler.gang.placement,0,"
              f"makespan_x={g['makespan_speedup']:.2f}")
    if "herd" in res:
        hd = res["herd"]
        print(f"scheduler.herd,{hd['fair_backfill']['wall_s'] * 1e6:.0f},"
              f"n_herd={hd['fleet']['n_herd']}"
              f"_others_p95_fair="
              f"{hd['fair_backfill']['others_wait_p95_s']:.0f}s"
              f"_fifo={hd['fifo']['others_wait_p95_s']:.0f}s"
              f"_cut={hd['others_p95_cut'] * 100:.1f}%")
    if "elastic" in res:
        e = res["elastic"]
        el, st = e["elastic_spot"], e["static_ondemand"]
        print(f"scheduler.elastic.static,{st['wall_s'] * 1e6:.0f},"
              f"makespan={st['makespan_s']:.0f}s"
              f"_billed=${st['total_cost']:.2f}"
              f"_provisioned=${st['provisioned_cost']:.2f}")
        print(f"scheduler.elastic.spot,{el['wall_s'] * 1e6:.0f},"
              f"makespan={el['makespan_s']:.0f}s"
              f"_billed=${el['total_cost']:.2f}"
              f"_provisioned=${el['provisioned_cost']:.2f}"
              f"_preempted={el['preempted']}"
              f"_reclaims={el['spot_reclaims']}"
              f"_scale_ops={el['scale_ops']}"
              f"_max_lost={el['max_lost_work_s']:.0f}s")
        print(f"scheduler.elastic.saving,0,"
              f"billed_cut={e['cost_saving_billed'] * 100:.1f}%"
              f"_provisioned_cut="
              f"{e['cost_saving_provisioned'] * 100:.1f}%"
              f"_makespan_ratio={e['makespan_ratio']:.3f}"
              f"_int_wait_p95={el['interactive_wait_p95_s']:.0f}s")
    if "chaos" in res:
        ch = res["chaos"]
        for tag in ("no_retry", "retry"):
            r = ch[tag]
            print(f"scheduler.chaos.{tag},{r['wall_s'] * 1e6:.0f},"
                  f"goodput={r['goodput_work_s_per_s']:.2f}"
                  f"_finished={r['finished']}"
                  f"_failed={r['failed']}"
                  f"_retried={r['retried']}"
                  f"_quarantined={r['quarantined']}"
                  f"_timeouts={r['timeouts']}"
                  f"_node_failures={r['node_failures']}"
                  f"_wasted={r['retry_wasted_s']:.0f}s")
        print(f"scheduler.chaos.gate,0,"
              f"goodput_x={ch['goodput_ratio']:.2f}"
              f"_faults={ch['injected_faults']}"
              f"_golden_match={str(ch['golden_match']).lower()}")
    if "recovery" in res:
        rc = res["recovery"]
        print(f"scheduler.recovery,{rc['recovery_wall_s'] * 1e6:.0f},"
              f"n={rc['n_jobs']}"
              f"_killed_at={rc['killed_at_completions']}"
              f"_replayed={rc['events_replayed']}"
              f"_requeued={rc['requeued']}"
              f"_lost={rc['lost_jobs']}"
              f"_dup={rc['duplicate_terminals']}"
              f"_match={str(rc['states_match_golden']).lower()}")
    if "scale" in res:
        sc = res["scale"]
        pools = ",".join(f"{p}:{c}" for p, c in
                         sorted(sc["placed_by_pool"].items()))
        print(f"scheduler.scale,{sc['wall_s'] * 1e6:.0f},"
              f"n={sc['fleet']['n_jobs']}"
              f"_users={sc['fleet']['n_users']}"
              f"_events_per_s={sc['sched_events_per_s']:.0f}"
              f"_pools={pools}"
              f"_oversubscribed={str(sc['oversubscribed']).lower()}")
    if write:
        with open("BENCH_scheduler.json", "w") as f:
            json.dump(res, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fleets, no JSON — the CI regression gate "
                         "(fails on a >30%% scheduler-throughput drop "
                         "vs the committed BENCH_scheduler.json)")
    ap.add_argument("--trace", default=None,
                    help="JSONL arrival trace replayed instead of the "
                         "synthetic Poisson fleet (policy scenario)")
    ap.add_argument("--n-jobs", type=int, default=None)
    ap.add_argument("--scale", type=int, default=None, metavar="N",
                    help=f"scale-scenario job count (default "
                         f"{SCALE_JOBS}; 0 disables the scenario)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile the fair+backfill policy run and dump "
                         "the top-20 functions by cumulative time")
    args = ap.parse_args()
    if args.profile:
        import cProfile
        import pstats
        arrivals = trace_arrivals(args.trace) if args.trace else \
            poisson_arrivals(make_fleet(0, args.n_jobs or N_JOBS),
                             ARRIVAL_RATE, 0)
        prof = cProfile.Profile()
        prof.enable()
        res = run_policy(arrivals, "fair", backfill=True)
        prof.disable()
        print(f"scheduler.profile,0,"
              f"events_per_s={res['sched_events_per_s']:.0f}")
        pstats.Stats(prof).sort_stats("cumulative").print_stats(20)
        return
    if args.smoke:
        # 5 min-wall repeats: the throughput gate compares absolute
        # events/s against the committed numbers, so squeeze out CI
        # runner noise (the 400-job fleet makes repeats cheap)
        res = run(n_jobs=args.n_jobs or 400, hetero_jobs=400,
                  trace=args.trace, scale_jobs=args.scale or 0,
                  policy_repeats=5, elastic_jobs=300,
                  gang_jobs=150, herd_jobs=1500, recovery_jobs=800,
                  feedback_jobs=400, chaos_jobs=250)
        report(res, write=False)
        failures = check_throughput_regression(res, "BENCH_scheduler.json")
        if failures:
            for f in failures:
                print(f"scheduler.smoke.REGRESSION,{f}")
            raise SystemExit(1)
        print("scheduler.smoke,0,ok")
    else:
        res = run(n_jobs=args.n_jobs or N_JOBS, trace=args.trace,
                  scale_jobs=SCALE_JOBS if args.scale is None
                  else args.scale)
        report(res)


if __name__ == "__main__":
    main()
