"""Scheduler benchmark — throughput, queue wait and makespan of a mixed
5k-job fleet on finite cluster capacity, FIFO vs fair-share + EASY
backfill.

The fleet mirrors the ACAI workload mix (§3.3, §4.2.2): a large majority
of small, short profiling jobs (the auto-provisioner's exploration grids)
sharing capacity with a minority of big, long training jobs. Under strict
global FIFO a blocked 8-vCPU training job convoys everything behind it
while capacity sits idle; fair-share + backfill slots profiling jobs into
the holes. The virtual clock makes both runs deterministic, and an
auditing cluster proves capacity is never oversubscribed on any dimension.

Emits ``BENCH_scheduler.json`` so future PRs have a perf trajectory:
  {policy: {makespan_s, mean_queue_wait_s, throughput_jobs_per_hour,
            backfilled, oversubscribed, wall_s}}
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.engine.cluster import Cluster
from repro.core.engine.events import EventBus
from repro.core.engine.launcher import VirtualRunner
from repro.core.engine.lifecycle import JobState
from repro.core.engine.registry import JobRegistry, JobSpec
from repro.core.engine.scheduler import Scheduler
from repro.core.provision.pricing import CPU_PRICING

N_JOBS = 5000
N_USERS = 8
NODES = 2               # 16 vCPU / 16 GB total — heavy contention


class AuditingCluster(Cluster):
    """Records the reservation high-water mark per dimension."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.high_water = {n: 0.0 for n in self.capacity}

    def reserve(self, job_id, resources):
        req = super().reserve(job_id, resources)
        for n in self.capacity:
            self.high_water[n] = max(self.high_water[n], self.used[n])
        return req


def make_fleet(seed: int = 0, n_jobs: int = N_JOBS) -> list[JobSpec]:
    rng = np.random.default_rng(seed)
    fleet = []
    for i in range(n_jobs):
        user = f"u{int(rng.integers(N_USERS))}"
        if rng.random() < 0.9:       # profiling job: small + short
            spec = JobSpec(
                name=f"prof-{i}", project="bench", user=user,
                duration=float(rng.uniform(5.0, 60.0)),
                resources={"vcpu": float(rng.choice([0.5, 1.0, 2.0])),
                           "mem_mb": float(rng.choice([512, 1024, 2048]))})
        else:                        # training job: big + long
            spec = JobSpec(
                name=f"train-{i}", project="bench", user=user,
                duration=float(rng.uniform(300.0, 900.0)),
                resources={"vcpu": 8.0, "mem_mb": 8192.0})
        fleet.append(spec)
    return fleet


def run_policy(fleet: list[JobSpec], policy: str, backfill: bool) -> dict:
    registry = JobRegistry()
    bus = EventBus()
    runner = VirtualRunner(registry, bus)
    cluster = AuditingCluster(
        {n: max(d.values) * NODES for n, d in CPU_PRICING.dims.items()},
        {n: d.minimum for n, d in CPU_PRICING.dims.items()})
    sched = Scheduler(registry, runner, bus, quota_k=16, cluster=cluster,
                      policy=policy, backfill=backfill, backfill_depth=50)
    t0 = time.perf_counter()
    for spec in fleet:
        sched.submit(registry.submit(JobSpec(**spec.__dict__)))
    sched.run_to_completion()
    wall = time.perf_counter() - t0
    finished = sum(1 for j in registry.all_jobs()
                   if j.state == JobState.FINISHED)
    assert finished == len(fleet), f"{finished}/{len(fleet)} finished"
    oversubscribed = any(
        cluster.high_water[n] > cluster.capacity[n] + 1e-9
        for n in cluster.capacity)
    makespan = runner.now
    return {
        "policy": f"{policy}+backfill" if backfill else policy,
        "n_jobs": len(fleet),
        "makespan_s": makespan,
        "mean_queue_wait_s": sched.mean_queue_wait(),
        "throughput_jobs_per_hour": len(fleet) / (makespan / 3600.0),
        "backfilled": sched.stats["backfilled"],
        "oversubscribed": oversubscribed,
        "peak_vcpu": cluster.high_water["vcpu"],
        "capacity_vcpu": cluster.capacity["vcpu"],
        "wall_s": wall,
        "sched_events_per_s": len(fleet) * 2 / max(wall, 1e-9),
    }


def run(n_jobs: int = N_JOBS, seed: int = 0) -> dict:
    fleet = make_fleet(seed, n_jobs)
    fifo = run_policy(fleet, "fifo", backfill=False)
    fair = run_policy(fleet, "fair", backfill=True)
    out = {
        "fleet": {"n_jobs": n_jobs, "n_users": N_USERS, "nodes": NODES},
        "fifo": fifo,
        "fair_backfill": fair,
        "makespan_speedup": fifo["makespan_s"] / fair["makespan_s"],
        "queue_wait_reduction":
            1.0 - fair["mean_queue_wait_s"] / fifo["mean_queue_wait_s"],
    }
    assert not fifo["oversubscribed"] and not fair["oversubscribed"]
    return out


def report(res: dict) -> None:
    """Print the CSV contract lines and write BENCH_scheduler.json —
    shared between standalone runs and benchmarks/run.py."""
    for name in ("fifo", "fair_backfill"):
        r = res[name]
        print(f"scheduler.{name},{r['wall_s'] * 1e6:.0f},"
              f"makespan={r['makespan_s']:.0f}s"
              f"_wait={r['mean_queue_wait_s']:.0f}s"
              f"_backfilled={r['backfilled']}")
    print(f"scheduler.speedup,0,makespan_x={res['makespan_speedup']:.3f}"
          f"_wait_cut={res['queue_wait_reduction'] * 100:.1f}%")
    with open("BENCH_scheduler.json", "w") as f:
        json.dump(res, f, indent=1)


def main() -> None:
    report(run())


if __name__ == "__main__":
    main()
